//! The PPO training loop (SB3-faithful, Table 5 hyper-parameters).
//!
//! Rust drives everything; the network forward and the clipped-surrogate
//! Adam step run as AOT-compiled HLO through [`Engine`]. One call to
//! [`train_ppo`] trains one agent from one seed — Alg. 1 launches many.

use anyhow::Result;

use crate::gym::{ChipletGymEnv, VecEnv, OBS_DIM};
use crate::model::space::N_HEADS;
use crate::runtime::Engine;
use crate::util::Rng;

use super::categorical;
use super::init::init_params;
use super::rollout::RolloutBuffer;

/// PPO hyper-parameters. Defaults mirror the artifact manifest (Table 5);
/// the Fig. 7/8 benches override `episode_len` / `ent_coef`.
#[derive(Clone, Copy, Debug)]
pub struct PpoConfig {
    pub total_timesteps: usize,
    pub n_steps: usize,
    pub batch_size: usize,
    pub n_epoch: usize,
    pub learning_rate: f64,
    pub clip_range: f64,
    pub ent_coef: f64,
    pub gamma: f64,
    pub gae_lambda: f64,
    pub episode_len: usize,
    /// Raw env rewards are divided by this before GAE (VecNormalize-lite;
    /// reported statistics stay in raw units).
    pub reward_scale: f64,
    /// Rollout environments stepped in lock-step through
    /// [`crate::gym::VecEnv`]. Must divide `n_steps`. With 1 (the
    /// default) training is bit-identical to the classic single-env
    /// loop; larger values fill the rollout K transitions per
    /// `step_batch` call.
    pub n_envs: usize,
}

impl PpoConfig {
    /// Pull Table 5 defaults from the artifact manifest.
    pub fn from_manifest(engine: &Engine) -> PpoConfig {
        let h = &engine.manifest.hyper;
        PpoConfig {
            total_timesteps: h.total_timesteps,
            n_steps: h.n_steps,
            batch_size: h.batch_size,
            n_epoch: h.n_epoch,
            learning_rate: h.learning_rate,
            clip_range: h.clip_range,
            ent_coef: h.ent_coef,
            gamma: h.gamma,
            gae_lambda: h.gae_lambda,
            episode_len: h.episode_length,
            reward_scale: 100.0,
            n_envs: 1,
        }
    }

    /// Shrink the run for tests/benches while keeping the shape.
    pub fn quick(mut self, total: usize) -> PpoConfig {
        self.total_timesteps = total;
        self.n_steps = self.n_steps.min(total.max(self.batch_size));
        self
    }
}

/// Per-iteration training statistics (one point of the Fig. 7/8/9/10
/// convergence curves).
#[derive(Clone, Copy, Debug)]
pub struct IterStat {
    pub timesteps: usize,
    /// Mean episodic reward over the last ≤100 episodes (raw env units).
    pub ep_rew_mean: f64,
    /// Cost-model value = ep_rew_mean / episode_len (paper Fig. 7 note).
    pub cost_value: f64,
    pub loss: f64,
    pub entropy: f64,
    pub approx_kl: f64,
}

/// Output of one PPO training run.
#[derive(Clone, Debug)]
pub struct PpoTrace {
    pub history: Vec<IterStat>,
    pub best_action: [usize; N_HEADS],
    pub best_reward: f64,
    /// Deterministic (argmax) action of the final policy.
    pub final_policy_action: [usize; N_HEADS],
    pub timesteps: usize,
}

/// Train one PPO agent on the Chiplet-Gym environment.
pub fn train_ppo(
    engine: &Engine,
    env: &mut ChipletGymEnv,
    cfg: &PpoConfig,
    seed: u64,
) -> Result<PpoTrace> {
    let manifest = &engine.manifest;
    assert_eq!(
        manifest.action_dims,
        crate::model::space::ACTION_DIMS.to_vec(),
        "artifact action space != Rust design space — rebuild artifacts"
    );
    anyhow::ensure!(
        !env.space.placement_head,
        "the AOT'd policy network has no placement head: train with \
         placement = canonical/optimized, or rebuild artifacts with the \
         extra head"
    );
    env.episode_len = cfg.episode_len;

    let head_slices = manifest.head_slices();
    let hyper = [
        cfg.learning_rate as f32,
        cfg.clip_range as f32,
        cfg.ent_coef as f32,
    ];

    let mut rng = Rng::new(seed);
    let mut params = init_params(manifest, seed);
    let mut adam_m = vec![0f32; params.len()];
    let mut adam_v = vec![0f32; params.len()];
    let mut adam_t: u64 = 0;

    // Rollouts run through a VecEnv of K forks of `env` (best-so-far
    // and step counts merge back into `env` after training). With K = 1
    // the RNG stream and transitions are bit-identical to the classic
    // single-env loop.
    let n_envs = cfg.n_envs.max(1);
    assert!(
        cfg.n_steps % n_envs == 0,
        "n_steps {} must be divisible by n_envs {n_envs}",
        cfg.n_steps
    );
    let t_len = cfg.n_steps / n_envs;
    // Fork (not clone): workers start with zeroed counters so merging
    // their stats back never re-counts the caller env's own history.
    let mut vec_env = VecEnv::replicate(&env.fork(), n_envs);

    let mut buffer = RolloutBuffer::new(cfg.n_steps);
    let mut obs_batch = vec_env.reset_all();
    let mut actions = vec![[0usize; N_HEADS]; n_envs];
    let mut log_probs = vec![0f64; n_envs];
    let mut values = vec![0f32; n_envs];
    let mut obs_flat = vec![0f32; n_envs * OBS_DIM];
    let mut last_values = vec![0f32; n_envs];

    // episodic reward tracking (SB3's ep_info_buffer, window 100)
    let mut ep_acc = vec![0.0f64; n_envs];
    let mut recent_eps: Vec<f64> = Vec::new();

    // minibatch scratch
    let mb = cfg.batch_size;
    let mut mb_obs = vec![0f32; mb * OBS_DIM];
    let mut mb_act = vec![0i32; mb * N_HEADS];
    let mut mb_lp = vec![0f32; mb];
    let mut mb_adv = vec![0f32; mb];
    let mut mb_ret = vec![0f32; mb];

    let mut history = Vec::new();
    let mut steps = 0usize;

    // §Perf: the epoch-fused artifact turns the 320 per-minibatch HLO
    // calls of one iteration into a single call (EXPERIMENTS.md §Perf).
    // Only usable when the rollout is exactly n_steps and minibatches
    // tile it — always true here; the per-minibatch path remains for
    // tests and partial batches.
    let use_fused = engine.has_epochs() && cfg.n_steps % mb == 0;
    let minibatches_per_iter = cfg.n_epoch * (cfg.n_steps / mb);
    let mut perm_flat = vec![0i32; minibatches_per_iter * mb];

    while steps < cfg.total_timesteps {
        // ---- rollout (device-resident params via ForwardSession) ----
        buffer.clear();
        let session = engine.forward_session(&params)?;
        for t in 0..t_len {
            for e in 0..n_envs {
                let fwd = session.forward(&obs_batch[e])?;
                log_probs[e] = categorical::sample_action(
                    &fwd.logp_all,
                    &head_slices,
                    &mut rng,
                    &mut actions[e],
                );
                values[e] = fwd.value[0];
                // record exactly the observation the policy consumed
                // (bitwise equal to VecEnv::write_obs_flat's output, but
                // taken from the forward's input, not re-derived)
                obs_flat[e * OBS_DIM..(e + 1) * OBS_DIM].copy_from_slice(&obs_batch[e]);
            }
            // one step_batch call fills the K transitions of rollout row t
            let batch = vec_env.step_batch(&actions);
            buffer.push_step_batch(t, &obs_flat, &actions, &log_probs, &values, &batch);
            for (e, step) in batch.iter().enumerate() {
                ep_acc[e] += step.reward;
                if step.done {
                    recent_eps.push(ep_acc[e]);
                    if recent_eps.len() > 100 {
                        recent_eps.remove(0);
                    }
                    ep_acc[e] = 0.0;
                    obs_batch[e] = vec_env.reset(e);
                } else {
                    obs_batch[e] = step.obs;
                }
                steps += 1;
            }
        }
        for e in 0..n_envs {
            last_values[e] = session.forward(&obs_batch[e])?.value[0];
        }
        drop(session);
        buffer.compute_gae_batched(&last_values, cfg.gamma, cfg.gae_lambda, cfg.reward_scale);

        // ---- optimize: n_epoch passes of shuffled minibatches ----
        let mut last_stats = None;
        if use_fused {
            for epoch in 0..cfg.n_epoch {
                let perm = rng.permutation(cfg.n_steps);
                let base = epoch * cfg.n_steps;
                for (i, &p) in perm.iter().enumerate() {
                    perm_flat[base + i] = p as i32;
                }
            }
            let out = engine.ppo_epochs(
                &params,
                &adam_m,
                &adam_v,
                (adam_t + 1) as f32,
                &buffer.obs,
                &buffer.actions,
                &buffer.log_probs,
                &buffer.advantages,
                &buffer.returns,
                &perm_flat,
                hyper,
            )?;
            adam_t += minibatches_per_iter as u64;
            params = out.params;
            adam_m = out.adam_m;
            adam_v = out.adam_v;
            last_stats = Some(out.stats);
        } else {
            for _ in 0..cfg.n_epoch {
                let perm = rng.permutation(cfg.n_steps);
                for chunk in perm.chunks_exact(mb) {
                    buffer.gather(
                        chunk, &mut mb_obs, &mut mb_act, &mut mb_lp, &mut mb_adv,
                        &mut mb_ret,
                    );
                    adam_t += 1;
                    let out = engine.ppo_update(
                        &params,
                        &adam_m,
                        &adam_v,
                        adam_t as f32,
                        &mb_obs,
                        &mb_act,
                        &mb_lp,
                        &mb_adv,
                        &mb_ret,
                        hyper,
                    )?;
                    params = out.params;
                    adam_m = out.adam_m;
                    adam_v = out.adam_v;
                    last_stats = Some(out.stats);
                }
            }
        }

        let ep_rew_mean = if recent_eps.is_empty() {
            0.0
        } else {
            recent_eps.iter().sum::<f64>() / recent_eps.len() as f64
        };
        let s = last_stats.unwrap_or_default();
        history.push(IterStat {
            timesteps: steps,
            ep_rew_mean,
            cost_value: ep_rew_mean / cfg.episode_len as f64,
            loss: s.loss as f64,
            entropy: s.entropy as f64,
            approx_kl: s.approx_kl as f64,
        });
    }

    // The VecEnv clones discovered the designs; flow their argmax (and
    // step counts) back into the caller's env.
    for clone in vec_env.envs() {
        env.merge_best(clone);
    }

    // Deterministic action of the final policy.
    let final_obs = env.reset();
    let fwd = engine.policy_forward(&params, &final_obs)?;
    let mut final_action = [0usize; N_HEADS];
    categorical::argmax_action(&fwd.logp_all, &head_slices, &mut final_action);

    let (best_reward, best_point) = env
        .best()
        .map(|(r, p)| (r, env.space.encode(p)))
        .unwrap_or((f64::NEG_INFINITY, [0; N_HEADS]));

    Ok(PpoTrace {
        history,
        best_action: best_point,
        best_reward,
        final_policy_action: final_action,
        timesteps: steps,
    })
}

//! The PPO training loop (SB3-faithful, Table 5 hyper-parameters).
//!
//! Rust drives everything; the numerical kernels run through one of two
//! backends behind [`PpoBackend`]:
//!
//! * **AOT** — the compiled HLO artifacts via [`Engine`]. This is the
//!   validated fast path: before training, the artifact manifest's
//!   network shape is checked against the design space's
//!   [`ActionLayout`] (`NetShape::matches_manifest`), and a mismatch is
//!   a typed error, not a panic. On matching shapes the loop is
//!   bit-identical to the pre-refactor fixed-14-head implementation —
//!   same RNG stream, same buffers, same engine calls.
//! * **Native** — the pure-Rust [`NativeNet`] sized at runtime from the
//!   layout. Any layout trains, including the 15-head learned-placement
//!   space no frozen artifact knows about, and no artifacts are needed
//!   at all.
//!
//! One call to [`train_ppo_with`] trains one agent from one seed —
//! Alg. 1 launches many.

use std::collections::VecDeque;

use anyhow::{ensure, Result};

use crate::gym::{ChipletGymEnv, Step, VecEnv, OBS_DIM};
use crate::model::space::{Action, ActionLayout};
use crate::runtime::{Engine, ForwardOut, UpdateOut};
use crate::util::Rng;

use super::categorical;
use super::init::{init_param_entries, init_params};
use super::net::{NativeNet, NetShape};
use super::rollout::RolloutBuffer;

/// PPO hyper-parameters. Defaults mirror Table 5 ([`PpoConfig::paper`],
/// also what the artifact manifest carries); the Fig. 7/8 benches
/// override `episode_len` / `ent_coef`.
#[derive(Clone, Copy, Debug)]
pub struct PpoConfig {
    pub total_timesteps: usize,
    pub n_steps: usize,
    pub batch_size: usize,
    pub n_epoch: usize,
    pub learning_rate: f64,
    pub clip_range: f64,
    pub ent_coef: f64,
    pub gamma: f64,
    pub gae_lambda: f64,
    pub episode_len: usize,
    /// Raw env rewards are divided by this before GAE (VecNormalize-lite;
    /// reported statistics stay in raw units).
    pub reward_scale: f64,
    /// Rollout environments stepped in lock-step through
    /// [`crate::gym::VecEnv`]. Must divide `n_steps`. With 1 (the
    /// default) training is bit-identical to the classic single-env
    /// loop; larger values fill the rollout K transitions per
    /// `step_batch` call.
    pub n_envs: usize,
    /// Worker threads for the native backend's data-parallel path:
    /// env stepping, minibatch forward/backward shards and the Adam
    /// step all ride `util::pool`. `1` (the default) keeps every
    /// computation on the calling thread; `0` means all pool workers;
    /// any other value is clamped to the pool size. Results are
    /// bit-identical at every setting — shard geometry is fixed by the
    /// problem shape, never by the worker count. The AOT backend
    /// ignores this.
    pub jobs: usize,
}

impl PpoConfig {
    /// Table 5 of the paper (SB3 defaults + ent_coef 0.1) — the same
    /// numbers `model.py::HYPERPARAMS` bakes into the artifacts, usable
    /// without any artifacts present (the native-backend entry point).
    pub fn paper() -> PpoConfig {
        PpoConfig {
            total_timesteps: 250_000,
            n_steps: 2048,
            batch_size: 64,
            n_epoch: 10,
            learning_rate: 3e-4,
            clip_range: 0.2,
            ent_coef: 0.1,
            gamma: 0.99,
            gae_lambda: 0.95,
            episode_len: 2,
            reward_scale: 100.0,
            n_envs: 1,
            jobs: 1,
        }
    }

    /// Pull Table 5 defaults from the artifact manifest.
    pub fn from_manifest(engine: &Engine) -> PpoConfig {
        let h = &engine.manifest.hyper;
        PpoConfig {
            total_timesteps: h.total_timesteps,
            n_steps: h.n_steps,
            batch_size: h.batch_size,
            n_epoch: h.n_epoch,
            learning_rate: h.learning_rate,
            clip_range: h.clip_range,
            ent_coef: h.ent_coef,
            gamma: h.gamma,
            gae_lambda: h.gae_lambda,
            episode_len: h.episode_length,
            reward_scale: 100.0,
            n_envs: 1,
            jobs: 1,
        }
    }

    /// Shrink the run for tests/benches while keeping the shape.
    pub fn quick(mut self, total: usize) -> PpoConfig {
        self.total_timesteps = total;
        self.n_steps = self.n_steps.min(total.max(self.batch_size));
        self
    }
}

/// Per-iteration training statistics (one point of the Fig. 7/8/9/10
/// convergence curves).
#[derive(Clone, Copy, Debug)]
pub struct IterStat {
    pub timesteps: usize,
    /// Mean episodic reward over the last ≤100 episodes (raw env units).
    pub ep_rew_mean: f64,
    /// Cost-model value = ep_rew_mean / episode_len (paper Fig. 7 note).
    pub cost_value: f64,
    pub loss: f64,
    pub entropy: f64,
    pub approx_kl: f64,
}

/// Output of one PPO training run. Actions are runtime-sized
/// ([`Action`]): 14 entries on the Table 1 space, 15 with the
/// learned-placement head.
#[derive(Clone, Debug)]
pub struct PpoTrace {
    pub history: Vec<IterStat>,
    pub best_action: Action,
    pub best_reward: f64,
    /// Deterministic (argmax) action of the final policy.
    pub final_policy_action: Action,
    pub timesteps: usize,
}

/// Which numerical backend executes the policy network.
pub enum PpoBackend<'e> {
    /// AOT'd HLO artifacts through the PJRT engine — the validated fast
    /// path; shapes must match the space's layout.
    Aot(&'e Engine),
    /// Pure-Rust network sized from the layout (`rl::net`) — any layout,
    /// no artifacts required.
    Native,
}

/// Does `engine`'s artifact network match a space layout? (The
/// condition under which [`train_ppo_auto`] picks the AOT fast path.)
pub fn manifest_matches(engine: &Engine, layout: &ActionLayout) -> bool {
    NetShape::for_layout(layout).matches_manifest(&engine.manifest)
}

/// The single backend-selection predicate behind [`train_ppo_auto`] —
/// also what the CLI uses for its "RL backend" label, so the printed
/// choice can never drift from the trained one. `true` = the AOT path
/// (either the validated fast path, or — for a standard 14-head space
/// with mismatched artifacts — its typed stale-artifact error);
/// `false` = the native network.
pub fn aot_backend(engine: &Engine, layout: &ActionLayout) -> bool {
    manifest_matches(engine, layout) || layout.dims() == crate::model::space::ACTION_DIMS
}

/// Train one PPO agent on the AOT fast path (shapes validated against
/// the manifest; errors, not panics, on mismatch).
pub fn train_ppo(
    engine: &Engine,
    env: &mut ChipletGymEnv,
    cfg: &PpoConfig,
    seed: u64,
) -> Result<PpoTrace> {
    train_ppo_with(&PpoBackend::Aot(engine), env, cfg, seed)
}

/// Train one PPO agent on the native backend (no artifacts needed; the
/// network is sized from `env.space.layout()`).
pub fn train_ppo_native(env: &mut ChipletGymEnv, cfg: &PpoConfig, seed: u64) -> Result<PpoTrace> {
    train_ppo_with(&PpoBackend::Native, env, cfg, seed)
}

/// Backend auto-selection: the AOT fast path when an engine is present
/// *and* its artifact shapes match the space's layout; the native
/// network when there is no engine or the layout has grown beyond the
/// Table 1 heads the artifacts were traced for (learned placement).
///
/// A supplied engine whose artifacts fail to match a *standard* 14-head
/// space is a stale-artifact condition, not a fallback case: that
/// combination returns `train_ppo`'s typed shape-mismatch error instead
/// of silently training on the non-bit-compatible native backend.
pub fn train_ppo_auto(
    engine: Option<&Engine>,
    env: &mut ChipletGymEnv,
    cfg: &PpoConfig,
    seed: u64,
) -> Result<PpoTrace> {
    let layout = env.space.layout();
    match engine {
        Some(e) if aot_backend(e, &layout) => train_ppo_with(&PpoBackend::Aot(e), env, cfg, seed),
        _ => train_ppo_with(&PpoBackend::Native, env, cfg, seed),
    }
}

/// Executor over a chosen backend: one internal call surface for the
/// rollout forward, the per-minibatch update and the fused-epoch path.
enum Exec<'e> {
    Aot(&'e Engine),
    Native(NativeNet),
}

/// A rollout session: device-resident parameters on the AOT path, a
/// plain borrow on the native path.
enum Session<'a> {
    Aot(crate::runtime::ForwardSession<'a>),
    Native { net: &'a NativeNet, params: &'a [f32] },
}

impl Session<'_> {
    /// Forward into a caller-owned output. The native path is
    /// allocation-free in steady state (`NativeNet::forward_into`); the
    /// AOT path still materializes the engine's output and moves it in.
    fn forward_into(&self, obs: &[f32], out: &mut ForwardOut) -> Result<()> {
        match self {
            Session::Aot(s) => {
                *out = s.forward(obs)?;
                Ok(())
            }
            Session::Native { net, params } => net.forward_into(params, obs, out),
        }
    }
}

impl Exec<'_> {
    fn forward_session<'a>(&'a self, params: &'a [f32]) -> Result<Session<'a>> {
        match self {
            Exec::Aot(e) => Ok(Session::Aot(e.forward_session(params)?)),
            Exec::Native(n) => Ok(Session::Native { net: n, params }),
        }
    }

    fn policy_forward(&self, params: &[f32], obs: &[f32]) -> Result<ForwardOut> {
        match self {
            Exec::Aot(e) => e.policy_forward(params, obs),
            Exec::Native(n) => n.forward(params, obs),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn ppo_epochs(
        &self,
        params: &[f32],
        adam_m: &[f32],
        adam_v: &[f32],
        step0: f32,
        obs: &[f32],
        actions: &[i32],
        old_logp: &[f32],
        advantages: &[f32],
        returns: &[f32],
        perm: &[i32],
        hyper: [f32; 3],
    ) -> Result<UpdateOut> {
        match self {
            Exec::Aot(e) => e.ppo_epochs(
                params, adam_m, adam_v, step0, obs, actions, old_logp, advantages, returns,
                perm, hyper,
            ),
            Exec::Native(_) => unreachable!("native backend has no fused-epoch path"),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn ppo_update(
        &self,
        params: &[f32],
        adam_m: &[f32],
        adam_v: &[f32],
        step: f32,
        obs: &[f32],
        actions: &[i32],
        old_logp: &[f32],
        advantages: &[f32],
        returns: &[f32],
        hyper: [f32; 3],
    ) -> Result<UpdateOut> {
        match self {
            Exec::Aot(e) => e.ppo_update(
                params, adam_m, adam_v, step, obs, actions, old_logp, advantages, returns, hyper,
            ),
            Exec::Native(n) => n.ppo_update(
                params, adam_m, adam_v, step, obs, actions, old_logp, advantages, returns, hyper,
            ),
        }
    }
}

/// Train one PPO agent on the Chiplet-Gym environment over an explicit
/// backend. The loop is sized entirely from `env.space.layout()` — the
/// sampler, the rollout buffer and the network dimensions all follow the
/// runtime head count, so 14- and 15-head spaces run through one code
/// path.
pub fn train_ppo_with(
    backend: &PpoBackend<'_>,
    env: &mut ChipletGymEnv,
    cfg: &PpoConfig,
    seed: u64,
) -> Result<PpoTrace> {
    let layout = env.space.layout();
    let n_heads = layout.n_heads();
    let head_slices = layout.head_slices();

    // Backend setup: validate the AOT manifest against the layout (a
    // typed error, not a panic, when a scenario's space outgrows the
    // frozen artifacts), or size the native network from the layout.
    let (exec, params) = match backend {
        PpoBackend::Aot(engine) => {
            let m = &engine.manifest;
            ensure!(
                m.action_dims.as_slice() == layout.dims(),
                "artifact action space {:?} does not match this design space's layout {:?} — \
                 a `placement = learned` scenario (15th head) needs the native PPO backend or \
                 rebuilt artifacts",
                m.action_dims,
                layout.dims()
            );
            ensure!(
                m.obs_dim == OBS_DIM,
                "artifact obs_dim {} != environment OBS_DIM {OBS_DIM} — rebuild artifacts",
                m.obs_dim
            );
            let params = init_params(m, seed);
            (Exec::Aot(*engine), params)
        }
        PpoBackend::Native => {
            let shape = NetShape::for_layout(&layout);
            let params = init_param_entries(&shape.param_entries(), shape.param_count(), seed);
            (Exec::Native(NativeNet::new(shape).with_jobs(cfg.jobs)), params)
        }
    };

    env.episode_len = cfg.episode_len;
    let hyper = [
        cfg.learning_rate as f32,
        cfg.clip_range as f32,
        cfg.ent_coef as f32,
    ];

    let mut rng = Rng::new(seed);
    let mut params = params;
    let mut adam_m = vec![0f32; params.len()];
    let mut adam_v = vec![0f32; params.len()];
    let mut adam_t: u64 = 0;

    // Rollouts run through a VecEnv of K forks of `env` (best-so-far
    // and step counts merge back into `env` after training). With K = 1
    // the RNG stream and transitions are bit-identical to the classic
    // single-env loop.
    let n_envs = cfg.n_envs.max(1);
    ensure!(
        cfg.n_steps % n_envs == 0,
        "n_steps {} must be divisible by n_envs {n_envs}",
        cfg.n_steps
    );
    let t_len = cfg.n_steps / n_envs;
    // Fork (not clone): workers start with zeroed counters so merging
    // their stats back never re-counts the caller env's own history.
    let mut vec_env = VecEnv::replicate(&env.fork(), n_envs);

    let mut buffer = RolloutBuffer::new(cfg.n_steps, n_heads);
    let mut actions: Vec<Action> = vec![vec![0usize; n_heads]; n_envs];
    let mut log_probs = vec![0f64; n_envs];
    let mut values = vec![0f32; n_envs];
    // the K current observations, row-major — the single source the
    // forward consumes and the buffer records (no per-env copies)
    let mut obs_flat = vec![0f32; n_envs * OBS_DIM];
    vec_env.reset_all();
    vec_env.write_obs_flat(&mut obs_flat);
    let mut last_values = vec![0f32; n_envs];
    // reused per-step buffers: the rollout hot loop allocates nothing
    // in steady state
    let mut fwd = ForwardOut { logp_all: Vec::new(), value: Vec::new() };
    let mut step_buf: Vec<Step> = Vec::with_capacity(n_envs);

    // episodic reward tracking (SB3's ep_info_buffer, window 100)
    let mut ep_acc = vec![0.0f64; n_envs];
    let mut recent_eps: VecDeque<f64> = VecDeque::with_capacity(101);

    // minibatch scratch (rows sized from the runtime head count)
    let mb = cfg.batch_size;
    let mut mb_obs = vec![0f32; mb * OBS_DIM];
    let mut mb_act = vec![0i32; mb * n_heads];
    let mut mb_lp = vec![0f32; mb];
    let mut mb_adv = vec![0f32; mb];
    let mut mb_ret = vec![0f32; mb];
    // scratch for the native path's remainder minibatch (empty when the
    // batch size tiles the rollout)
    let rem_len = cfg.n_steps % mb;
    let mut rem_obs = vec![0f32; rem_len * OBS_DIM];
    let mut rem_act = vec![0i32; rem_len * n_heads];
    let mut rem_lp = vec![0f32; rem_len];
    let mut rem_adv = vec![0f32; rem_len];
    let mut rem_ret = vec![0f32; rem_len];

    let mut history = Vec::new();
    let mut steps = 0usize;

    // §Perf: the epoch-fused artifact turns the 320 per-minibatch HLO
    // calls of one iteration into a single call (EXPERIMENTS.md §Perf).
    // Only usable when the run's rollout/minibatch/epoch shape is
    // exactly what the artifact was traced with — a quick()-clamped
    // n_steps must fall back to the per-minibatch path, or ppo_epochs
    // rejects the buffers mid-run. The per-minibatch path also serves
    // the native backend (which additionally trains the remainder rows
    // of a non-tiling batch size — see below).
    let use_fused = match &exec {
        Exec::Aot(e) => {
            let h = &e.manifest.hyper;
            e.has_epochs()
                && cfg.n_steps == h.n_steps
                && cfg.batch_size == h.batch_size
                && cfg.n_epoch == h.n_epoch
                && cfg.n_steps % mb == 0
        }
        Exec::Native(_) => false,
    };
    let minibatches_per_iter = cfg.n_epoch * (cfg.n_steps / mb);
    let mut perm_flat = vec![0i32; minibatches_per_iter * mb];

    // On the native backend the K per-env policy forwards collapse into
    // one batched forward over all of `obs_flat`: the dense kernels
    // treat rows independently, so every row of the batched output is
    // bitwise identical to its single-row forward, and sampling still
    // walks envs in ascending order (the RNG stream is unchanged). The
    // AOT artifact is traced for single-row forwards and keeps the
    // per-env loop.
    let batched_fwd = matches!(exec, Exec::Native(_)) && n_envs > 1;
    let act_total = head_slices.last().map_or(0, |&(_, end)| end);
    // Env stepping fans the K independent env transitions out over the
    // shared worker pool when `jobs` allows more than one thread.
    let env_jobs = if cfg.jobs == 1 {
        1
    } else {
        crate::util::pool::resolve_jobs(cfg.jobs)
    };

    while steps < cfg.total_timesteps {
        // ---- rollout (device-resident params via ForwardSession) ----
        buffer.clear();
        let session = exec.forward_session(&params)?;
        for t in 0..t_len {
            if batched_fwd {
                // one forward over all K rows of obs_flat; rows are
                // independent, so env e's slice is bitwise the same as
                // its single-row forward
                session.forward_into(&obs_flat, &mut fwd)?;
                for e in 0..n_envs {
                    log_probs[e] = categorical::sample_action(
                        &fwd.logp_all[e * act_total..(e + 1) * act_total],
                        &head_slices,
                        &mut rng,
                        &mut actions[e],
                    );
                    values[e] = fwd.value[e];
                }
            } else {
                for e in 0..n_envs {
                    // the policy consumes its env's row of obs_flat
                    // directly; the same rows are what the buffer
                    // records below
                    session.forward_into(&obs_flat[e * OBS_DIM..(e + 1) * OBS_DIM], &mut fwd)?;
                    log_probs[e] = categorical::sample_action(
                        &fwd.logp_all,
                        &head_slices,
                        &mut rng,
                        &mut actions[e],
                    );
                    values[e] = fwd.value[0];
                }
            }
            // one step_batch call fills the K transitions of rollout row t
            vec_env.step_batch_par_into(&actions, &mut step_buf, env_jobs);
            buffer.push_step_batch(t, &obs_flat, &actions, &log_probs, &values, &step_buf);
            for (e, step) in step_buf.iter().enumerate() {
                ep_acc[e] += step.reward;
                let row = &mut obs_flat[e * OBS_DIM..(e + 1) * OBS_DIM];
                if step.done {
                    recent_eps.push_back(ep_acc[e]);
                    if recent_eps.len() > 100 {
                        recent_eps.pop_front();
                    }
                    ep_acc[e] = 0.0;
                    row.copy_from_slice(&vec_env.reset(e));
                } else {
                    row.copy_from_slice(&step.obs);
                }
                steps += 1;
            }
        }
        if batched_fwd {
            session.forward_into(&obs_flat, &mut fwd)?;
            last_values.copy_from_slice(&fwd.value);
        } else {
            for e in 0..n_envs {
                session.forward_into(&obs_flat[e * OBS_DIM..(e + 1) * OBS_DIM], &mut fwd)?;
                last_values[e] = fwd.value[0];
            }
        }
        drop(session);
        buffer.compute_gae_batched(&last_values, cfg.gamma, cfg.gae_lambda, cfg.reward_scale);

        // ---- optimize: n_epoch passes of shuffled minibatches ----
        let mut last_stats = None;
        if use_fused {
            for epoch in 0..cfg.n_epoch {
                let perm = rng.permutation(cfg.n_steps);
                let base = epoch * cfg.n_steps;
                for (i, &p) in perm.iter().enumerate() {
                    perm_flat[base + i] = p as i32;
                }
            }
            let out = exec.ppo_epochs(
                &params,
                &adam_m,
                &adam_v,
                (adam_t + 1) as f32,
                &buffer.obs,
                &buffer.actions,
                &buffer.log_probs,
                &buffer.advantages,
                &buffer.returns,
                &perm_flat,
                hyper,
            )?;
            adam_t += minibatches_per_iter as u64;
            params = out.params;
            adam_m = out.adam_m;
            adam_v = out.adam_v;
            last_stats = Some(out.stats);
        } else {
            for _ in 0..cfg.n_epoch {
                let perm = rng.permutation(cfg.n_steps);
                let mut chunks = perm.chunks_exact(mb);
                for chunk in &mut chunks {
                    buffer.gather(
                        chunk, &mut mb_obs, &mut mb_act, &mut mb_lp, &mut mb_adv,
                        &mut mb_ret,
                    );
                    adam_t += 1;
                    let out = exec.ppo_update(
                        &params,
                        &adam_m,
                        &adam_v,
                        adam_t as f32,
                        &mb_obs,
                        &mb_act,
                        &mb_lp,
                        &mb_adv,
                        &mb_ret,
                        hyper,
                    )?;
                    params = out.params;
                    adam_m = out.adam_m;
                    adam_v = out.adam_v;
                    last_stats = Some(out.stats);
                }
                // When batch_size does not tile n_steps (a scenario
                // budget below 2048 can do this), the native backend
                // trains the leftover rows as one short minibatch — no
                // rollout data is silently dropped. The AOT update
                // artifact is traced at a fixed minibatch shape, so on
                // that path the remainder is skipped, exactly as the
                // pre-refactor loop did (bit-identity preserved).
                let rem = chunks.remainder();
                if !rem.is_empty() && matches!(exec, Exec::Native(_)) {
                    debug_assert_eq!(rem.len(), rem_len);
                    buffer.gather(
                        rem, &mut rem_obs, &mut rem_act, &mut rem_lp, &mut rem_adv,
                        &mut rem_ret,
                    );
                    adam_t += 1;
                    let out = exec.ppo_update(
                        &params,
                        &adam_m,
                        &adam_v,
                        adam_t as f32,
                        &rem_obs,
                        &rem_act,
                        &rem_lp,
                        &rem_adv,
                        &rem_ret,
                        hyper,
                    )?;
                    params = out.params;
                    adam_m = out.adam_m;
                    adam_v = out.adam_v;
                    last_stats = Some(out.stats);
                }
            }
        }

        let ep_rew_mean = if recent_eps.is_empty() {
            0.0
        } else {
            recent_eps.iter().sum::<f64>() / recent_eps.len() as f64
        };
        let s = last_stats.unwrap_or_default();
        history.push(IterStat {
            timesteps: steps,
            ep_rew_mean,
            cost_value: ep_rew_mean / cfg.episode_len as f64,
            loss: s.loss as f64,
            entropy: s.entropy as f64,
            approx_kl: s.approx_kl as f64,
        });
    }

    // The VecEnv clones discovered the designs; flow their argmax (and
    // step counts) back into the caller's env.
    for clone in vec_env.envs() {
        env.merge_best(clone);
    }

    // Deterministic action of the final policy.
    let final_obs = env.reset();
    let fwd = exec.policy_forward(&params, &final_obs)?;
    let mut final_action = vec![0usize; n_heads];
    categorical::argmax_action(&fwd.logp_all, &head_slices, &mut final_action);

    let (best_reward, best_action) = env
        .best_action()
        .unwrap_or((f64::NEG_INFINITY, vec![0; n_heads]));

    Ok(PpoTrace {
        history,
        best_action,
        best_reward,
        final_policy_action: final_action,
        timesteps: steps,
    })
}

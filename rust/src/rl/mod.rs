//! PPO over a runtime-sized MultiDiscrete action space — Section 4.1.
//!
//! The Rust side owns everything stochastic and sequential: parameter
//! initialization, rollouts through the Chiplet-Gym environment,
//! MultiDiscrete sampling, GAE, minibatch shuffling and the Adam step
//! counter. The two numerical kernels — policy forward and the clipped
//! PPO gradient step — execute through one of two [`ppo::PpoBackend`]s:
//! the AOT'd HLO artifacts via [`crate::runtime::Engine`] (the validated
//! fast path, when the manifest's shapes match the space's
//! `ActionLayout`) or the pure-Rust [`net::NativeNet`] sized from the
//! layout (any head count, no artifacts — the path `placement =
//! learned` trains through).

pub mod categorical;
pub mod init;
pub mod net;
pub mod ppo;
pub mod rollout;

pub use net::{NativeNet, NetShape};
pub use ppo::{
    aot_backend, manifest_matches, train_ppo, train_ppo_auto, train_ppo_native, train_ppo_with,
    PpoBackend, PpoConfig, PpoTrace,
};

//! PPO over the AOT'd JAX/Pallas network — Section 4.1 of the paper.
//!
//! The Rust side owns everything stochastic and sequential: parameter
//! initialization, rollouts through the Chiplet-Gym environment,
//! MultiDiscrete sampling, GAE, minibatch shuffling and the Adam step
//! counter. The two numerical kernels — policy forward and the clipped
//! PPO gradient step — execute as compiled HLO through
//! [`crate::runtime::Engine`].

pub mod categorical;
pub mod init;
pub mod ppo;
pub mod rollout;

pub use ppo::{train_ppo, PpoConfig, PpoTrace};

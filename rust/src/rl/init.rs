//! Parameter initialization on the Rust side.
//!
//! Scaled-Gaussian initialization with the SB3 gain schedule (hidden
//! layers gain √2, policy head 0.01, value head 1.0; zero biases). The
//! Python compile path ships an orthogonal initializer for its golden
//! vectors; Gaussian-with-matched-scale is statistically equivalent for
//! these layer sizes and keeps seeds cheap on the Rust side (no QR).

use crate::runtime::Manifest;
use crate::util::Rng;

/// Gain for a parameter tensor by name (matches model.py's schedule).
fn gain(name: &str) -> f64 {
    match name {
        "pi_wh" => 0.01,
        "vf_wh" => 1.0,
        _ => std::f64::consts::SQRT_2,
    }
}

/// Initialize a flat parameter vector per the manifest layout.
pub fn init_params(manifest: &Manifest, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x9e37_79b9);
    let mut flat = vec![0f32; manifest.param_count];
    for entry in &manifest.params {
        if entry.shape.len() == 1 {
            continue; // biases stay zero
        }
        let fan_in = entry.shape[0] as f64;
        let std = gain(&entry.name) / fan_in.sqrt();
        for x in &mut flat[entry.offset..entry.offset + entry.size] {
            *x = rng.normal_ms(0.0, std) as f32;
        }
    }
    flat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn manifest() -> Manifest {
        // A small synthetic manifest exercising the layout logic.
        let json = r#"{
          "obs_dim": 4, "hidden": 8, "action_dims": [2, 3], "act_total": 5,
          "n_heads": 2, "param_count": 61, "eval_batch": 8,
          "params": [
            {"name": "pi_w1", "shape": [4, 8], "offset": 0, "size": 32},
            {"name": "pi_b1", "shape": [8], "offset": 32, "size": 8},
            {"name": "pi_wh", "shape": [2, 8], "offset": 40, "size": 16},
            {"name": "vf_bh", "shape": [5], "offset": 56, "size": 5}
          ],
          "hyperparams": {"n_steps": 8, "batch_size": 4, "n_epoch": 2,
            "learning_rate": 0.001, "clip_range": 0.2, "ent_coef": 0.1,
            "vf_coef": 0.5, "gamma": 0.99, "gae_lambda": 0.95,
            "max_grad_norm": 0.5, "total_timesteps": 100,
            "episode_length": 2},
          "artifacts": {"policy_forward": "f", "policy_forward_b64": "fb",
            "ppo_update": "u"}
        }"#;
        Manifest::from_json(&Json::parse(json).unwrap()).unwrap()
    }

    #[test]
    fn biases_zero_weights_nonzero() {
        let m = manifest();
        let p = init_params(&m, 0);
        assert_eq!(p.len(), 61);
        assert!(p[32..40].iter().all(|&x| x == 0.0)); // pi_b1
        assert!(p[56..61].iter().all(|&x| x == 0.0)); // vf_bh
        assert!(p[0..32].iter().any(|&x| x != 0.0)); // pi_w1
    }

    #[test]
    fn head_weights_are_small() {
        let m = manifest();
        let p = init_params(&m, 1);
        let head_max = p[40..56].iter().fold(0f32, |a, &x| a.max(x.abs()));
        let body_max = p[0..32].iter().fold(0f32, |a, &x| a.max(x.abs()));
        assert!(head_max < body_max / 5.0, "head {head_max} body {body_max}");
    }

    #[test]
    fn different_seeds_differ() {
        let m = manifest();
        assert_ne!(init_params(&m, 0), init_params(&m, 1));
        assert_eq!(init_params(&m, 2), init_params(&m, 2));
    }

    #[test]
    fn hidden_std_matches_gain() {
        let m = manifest();
        let p = init_params(&m, 3);
        let w = &p[0..32]; // fan_in 4, gain sqrt2 -> std ~0.707
        let mean: f32 = w.iter().sum::<f32>() / 32.0;
        let var: f32 = w.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / 32.0;
        let std = var.sqrt();
        assert!((0.3..1.3).contains(&std), "std {std}");
    }
}

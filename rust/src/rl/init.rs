//! Parameter initialization on the Rust side.
//!
//! Scaled-Gaussian initialization with the SB3 gain schedule (hidden
//! layers gain √2, policy head 0.01, value head 1.0; zero biases). The
//! Python compile path ships an orthogonal initializer for its golden
//! vectors; Gaussian-with-matched-scale is statistically equivalent for
//! these layer sizes and keeps seeds cheap on the Rust side (no QR).

use crate::runtime::{Manifest, ParamEntry};
use crate::util::Rng;

/// Gain for a parameter tensor by name (matches model.py's schedule).
fn gain(name: &str) -> f64 {
    match name {
        "pi_wh" => 0.01,
        "vf_wh" => 1.0,
        _ => std::f64::consts::SQRT_2,
    }
}

/// Initialize a flat parameter vector over an explicit tensor layout —
/// the shared core of the manifest path ([`init_params`]) and the
/// layout-sized native path (`rl::net::NetShape::param_entries`). Both
/// feed the same `(name, shape, offset)` entries through the same RNG
/// stream, so whenever the shapes agree the two paths produce
/// bit-identical vectors (pinned in the tests below).
pub fn init_param_entries(entries: &[ParamEntry], param_count: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x9e37_79b9);
    let mut flat = vec![0f32; param_count];
    for entry in entries {
        if entry.shape.len() == 1 {
            continue; // biases stay zero
        }
        let fan_in = entry.shape[0] as f64;
        let std = gain(&entry.name) / fan_in.sqrt();
        for x in &mut flat[entry.offset..entry.offset + entry.size] {
            *x = rng.normal_ms(0.0, std) as f32;
        }
    }
    flat
}

/// Initialize a flat parameter vector per the manifest layout.
pub fn init_params(manifest: &Manifest, seed: u64) -> Vec<f32> {
    init_param_entries(&manifest.params, manifest.param_count, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn manifest() -> Manifest {
        // A small synthetic manifest exercising the layout logic.
        let json = r#"{
          "obs_dim": 4, "hidden": 8, "action_dims": [2, 3], "act_total": 5,
          "n_heads": 2, "param_count": 61, "eval_batch": 8,
          "params": [
            {"name": "pi_w1", "shape": [4, 8], "offset": 0, "size": 32},
            {"name": "pi_b1", "shape": [8], "offset": 32, "size": 8},
            {"name": "pi_wh", "shape": [2, 8], "offset": 40, "size": 16},
            {"name": "vf_bh", "shape": [5], "offset": 56, "size": 5}
          ],
          "hyperparams": {"n_steps": 8, "batch_size": 4, "n_epoch": 2,
            "learning_rate": 0.001, "clip_range": 0.2, "ent_coef": 0.1,
            "vf_coef": 0.5, "gamma": 0.99, "gae_lambda": 0.95,
            "max_grad_norm": 0.5, "total_timesteps": 100,
            "episode_length": 2},
          "artifacts": {"policy_forward": "f", "policy_forward_b64": "fb",
            "ppo_update": "u"}
        }"#;
        Manifest::from_json(&Json::parse(json).unwrap()).unwrap()
    }

    #[test]
    fn biases_zero_weights_nonzero() {
        let m = manifest();
        let p = init_params(&m, 0);
        assert_eq!(p.len(), 61);
        assert!(p[32..40].iter().all(|&x| x == 0.0)); // pi_b1
        assert!(p[56..61].iter().all(|&x| x == 0.0)); // vf_bh
        assert!(p[0..32].iter().any(|&x| x != 0.0)); // pi_w1
    }

    #[test]
    fn head_weights_are_small() {
        let m = manifest();
        let p = init_params(&m, 1);
        let head_max = p[40..56].iter().fold(0f32, |a, &x| a.max(x.abs()));
        let body_max = p[0..32].iter().fold(0f32, |a, &x| a.max(x.abs()));
        assert!(head_max < body_max / 5.0, "head {head_max} body {body_max}");
    }

    /// Manifest JSON describing exactly the network `shape` induces.
    fn manifest_json_for(shape: &crate::rl::net::NetShape) -> String {
        let entries = shape.param_entries();
        let params: Vec<String> = entries
            .iter()
            .map(|e| {
                format!(
                    r#"{{"name": "{}", "shape": {:?}, "offset": {}, "size": {}}}"#,
                    e.name, e.shape, e.offset, e.size
                )
            })
            .collect();
        format!(
            r#"{{
              "obs_dim": {}, "hidden": {}, "action_dims": {:?},
              "act_total": {}, "n_heads": {}, "param_count": {},
              "eval_batch": 8,
              "params": [{}],
              "hyperparams": {{"n_steps": 8, "batch_size": 4, "n_epoch": 2,
                "learning_rate": 0.001, "clip_range": 0.2, "ent_coef": 0.1,
                "vf_coef": 0.5, "gamma": 0.99, "gae_lambda": 0.95,
                "max_grad_norm": 0.5, "total_timesteps": 100,
                "episode_length": 2}},
              "artifacts": {{"policy_forward": "f", "policy_forward_b64": "fb",
                "ppo_update": "u"}}
            }}"#,
            shape.obs_dim,
            shape.hidden,
            shape.dims,
            shape.act_total(),
            shape.n_heads(),
            shape.param_count(),
            params.join(",")
        )
    }

    #[test]
    fn manifest_and_layout_paths_are_bit_identical_on_matching_shapes() {
        // The AOT fast path must hand the engine the same initial
        // parameter vector the native path would build for the same
        // network: build a real Manifest from the native layout, check
        // it passes the fast-path guard, and compare the two
        // initializer entry points bit for bit.
        use crate::model::space::DesignSpace;
        use crate::rl::init::init_param_entries;
        use crate::rl::net::NetShape;
        let shape = NetShape::for_layout(&DesignSpace::case_i().layout());
        let json = Json::parse(&manifest_json_for(&shape)).unwrap();
        let m = Manifest::from_json(&json).unwrap();
        assert!(shape.matches_manifest(&m), "guard must accept its own layout");
        let entries = shape.param_entries();
        for seed in [0u64, 1, 42] {
            let aot = init_params(&m, seed);
            let native = init_param_entries(&entries, shape.param_count(), seed);
            assert_eq!(aot, native, "seed {seed}");
            assert!(aot.iter().any(|&x| x != 0.0));
        }
        // a manifest whose tensor *names* differ (same sizes/offsets)
        // would initialize differently (the gain schedule is by name) —
        // the entry-level guard must reject it.
        let renamed = manifest_json_for(&shape).replace("\"pi_wh\"", "\"pi_w9\"");
        let m2 = Manifest::from_json(&Json::parse(&renamed).unwrap()).unwrap();
        assert!(!shape.matches_manifest(&m2), "renamed tensor must fail the guard");
        assert_ne!(init_params(&m2, 0), init_params(&m, 0));
    }

    #[test]
    fn different_seeds_differ() {
        let m = manifest();
        assert_ne!(init_params(&m, 0), init_params(&m, 1));
        assert_eq!(init_params(&m, 2), init_params(&m, 2));
    }

    #[test]
    fn hidden_std_matches_gain() {
        let m = manifest();
        let p = init_params(&m, 3);
        let w = &p[0..32]; // fan_in 4, gain sqrt2 -> std ~0.707
        let mean: f32 = w.iter().sum::<f32>() / 32.0;
        let var: f32 = w.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / 32.0;
        let std = var.sqrt();
        assert!((0.3..1.3).contains(&std), "std {std}");
    }
}

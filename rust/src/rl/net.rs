//! Native policy/value network: the pure-Rust twin of `model.py`, sized
//! at runtime from an [`ActionLayout`].
//!
//! The AOT'd HLO artifacts freeze the network around the 14 Table 1
//! heads (591 logits), so any space whose layout differs — above all
//! `placement = learned`, which grows a 15th head — could not train at
//! all. This module removes that ceiling: the same actor-critic MLP
//! (`obs → 64 → 64 tanh` trunk for policy and value, per-head
//! log-softmax, SB3-semantics clipped-PPO update with global grad-norm
//! clipping and bias-corrected Adam) implemented directly over `f32`
//! slices, with the parameter vector laid out exactly like
//! `model.py::param_spec()` — so on 14-head spaces the manifest path and
//! the native path share one initializer and one flat-vector layout, and
//! `rl::train_ppo` can treat the engine as a validated fast path.
//!
//! Numerics are plain IEEE `f32` with `f64` reduction accumulators; the
//! native path makes no bit-compatibility claim against XLA (the AOT
//! path is still the bit-pinned one), only algorithmic equivalence —
//! `tests/rl_native.rs` checks the gradient against finite differences
//! and the training loop against a frozen pre-refactor oracle.
//!
//! Inner loops run on the blocked kernels in [`crate::kernels`]
//! (`dense` forward/backward, fused `adam`), which are bitwise
//! identical to the scalar loops they replaced — pinned against the
//! frozen [`crate::kernels::oracle::ScalarNet`] by `tests/kernels.rs`.
//! All per-call buffers live in a [`Scratch`] behind a `RefCell`, so
//! forwards and updates allocate nothing in steady state.

use std::cell::RefCell;

use anyhow::{ensure, Result};

use crate::kernels::{adam, dense};
use crate::model::space::ActionLayout;
use crate::runtime::{ForwardOut, ParamEntry, UpdateOut, UpdateStats};

use super::categorical;

/// Hidden width of both MLPs (SB3 `MlpPolicy` default, paper §5.2.1).
pub const HIDDEN: usize = 64;

// SB3 constants baked into the traced update artifact (model.py
// HYPERPARAMS); lr / clip / ent_coef stay runtime inputs via `hyper`.
const VF_COEF: f64 = 0.5;
const MAX_GRAD_NORM: f64 = 0.5;
const ADAM_BETA1: f64 = 0.9;
const ADAM_BETA2: f64 = 0.999;
const ADAM_EPS: f64 = 1e-5;
const ADV_EPS: f64 = 1e-8;

/// The network geometry one [`ActionLayout`] induces: observation and
/// hidden widths plus per-head cardinalities. This is the native
/// counterpart of the manifest's frozen `obs_dim`/`hidden`/`action_dims`
/// triple — [`NetShape::matches_manifest`] is exactly the fast-path
/// check `train_ppo` runs before trusting the AOT artifacts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetShape {
    pub obs_dim: usize,
    pub hidden: usize,
    pub dims: Vec<usize>,
}

impl NetShape {
    /// The paper's network over an arbitrary action layout.
    pub fn for_layout(layout: &ActionLayout) -> NetShape {
        NetShape {
            obs_dim: crate::gym::OBS_DIM,
            hidden: HIDDEN,
            dims: layout.dims().to_vec(),
        }
    }

    pub fn n_heads(&self) -> usize {
        self.dims.len()
    }

    /// Total policy logits (Σ head cardinalities).
    pub fn act_total(&self) -> usize {
        self.dims.iter().sum()
    }

    /// `(start, end)` logit ranges of each head.
    pub fn head_slices(&self) -> Vec<(usize, usize)> {
        ActionLayout::new(self.dims.clone()).head_slices()
    }

    /// The flat parameter layout, name-for-name and offset-for-offset
    /// the `model.py::param_spec()` order — which is what makes
    /// [`super::init::init_param_entries`] produce bit-identical vectors
    /// for the native and manifest paths whenever the shapes agree.
    pub fn param_entries(&self) -> Vec<ParamEntry> {
        let (o, h, a) = (self.obs_dim, self.hidden, self.act_total());
        let spec: [(&str, Vec<usize>); 12] = [
            ("pi_w1", vec![o, h]),
            ("pi_b1", vec![h]),
            ("pi_w2", vec![h, h]),
            ("pi_b2", vec![h]),
            ("pi_wh", vec![h, a]),
            ("pi_bh", vec![a]),
            ("vf_w1", vec![o, h]),
            ("vf_b1", vec![h]),
            ("vf_w2", vec![h, h]),
            ("vf_b2", vec![h]),
            ("vf_wh", vec![h, 1]),
            ("vf_bh", vec![1]),
        ];
        let mut out = Vec::with_capacity(spec.len());
        let mut off = 0;
        for (name, shape) in spec {
            let size: usize = shape.iter().product();
            out.push(ParamEntry { name: name.into(), shape, offset: off, size });
            off += size;
        }
        out
    }

    /// Scalars in the flat parameter vector.
    pub fn param_count(&self) -> usize {
        self.param_entries().iter().map(|e| e.size).sum()
    }

    /// Does an artifact manifest describe exactly this network? (The
    /// `train_ppo` AOT fast-path guard.) Beyond the aggregate dims,
    /// every parameter tensor's name/shape/offset/size must match the
    /// native layout entry for entry — the precise condition under
    /// which `init::init_param_entries` produces bit-identical vectors
    /// for the two backends.
    pub fn matches_manifest(&self, m: &crate::runtime::Manifest) -> bool {
        m.obs_dim == self.obs_dim
            && m.hidden == self.hidden
            && m.action_dims == self.dims
            && m.n_heads == self.dims.len()
            && m.act_total == self.act_total()
            && m.param_count == self.param_count()
            && m.params == self.param_entries()
    }
}

/// Offsets of every tensor inside the flat parameter vector.
#[derive(Clone, Copy, Debug)]
struct Offsets {
    pi_w1: usize,
    pi_b1: usize,
    pi_w2: usize,
    pi_b2: usize,
    pi_wh: usize,
    pi_bh: usize,
    vf_w1: usize,
    vf_b1: usize,
    vf_w2: usize,
    vf_b2: usize,
    vf_wh: usize,
    vf_bh: usize,
}

/// The native execution engine: stateless math over caller-owned flat
/// parameter vectors, mirroring the `runtime::Engine` call surface
/// (`forward` ≙ `policy_forward`, `ppo_update` ≙ the update artifact).
///
/// Not `Sync`: the reusable [`Scratch`] sits behind a `RefCell`, so a
/// net is single-threaded state — every rollout worker owns its own.
/// With `jobs > 1` ([`NativeNet::with_jobs`]) the forward/backward/Adam
/// phases dispatch output-sharded kernels through the global
/// `util::pool::WorkerPool` from the calling thread; the fixed shard
/// geometry keeps results bitwise identical to `jobs = 1` at any worker
/// count (`tests/parallel_determinism.rs` pins this).
#[derive(Clone, Debug)]
pub struct NativeNet {
    pub shape: NetShape,
    slices: Vec<(usize, usize)>,
    off: Offsets,
    /// Cached `shape.param_count()` — the per-step rollout forward
    /// validates against this without rebuilding the entry list.
    param_count: usize,
    /// `> 1`: shard forward/backward/Adam through the worker pool.
    jobs: usize,
    /// Reusable forward/backward buffers; see [`Scratch`].
    scratch: RefCell<Scratch>,
}

/// Every buffer a forward or update needs, owned by the net and reused
/// across calls — resized (never reallocated, in steady state) to the
/// current minibatch. Replaces the per-call `ForwardCache` Vecs and the
/// per-update grad/dlogits/dh/dpre allocations of the scalar era.
#[derive(Clone, Debug, Default)]
struct Scratch {
    // forward caches: [m × hidden] activations, [m × act_total] logp,
    // [m] values
    h1p: Vec<f32>,
    h2p: Vec<f32>,
    logp: Vec<f32>,
    h1v: Vec<f32>,
    h2v: Vec<f32>,
    val: Vec<f32>,
    /// `exp(logp)` per minibatch entry, computed once per update and
    /// shared by the entropy terms and the logit gradient (the scalar
    /// loop re-exponentiated three times).
    probs: Vec<f64>,
    /// Per-row d loss / d joint-logp.
    dlp: Vec<f64>,
    /// Per-row joint log-prob of the taken action.
    lps: Vec<f64>,
    // backward scratch
    dlogits: Vec<f64>,
    dh: Vec<f64>,
    dpre: Vec<f64>,
    grad: Vec<f32>,
    // whole-minibatch backward buffers for the parallel (`jobs > 1`)
    // path: [m × act_total] logit grads, [m × hidden] activation /
    // pre-activation grads, and the Adam per-entry update scratch
    dlogits_all: Vec<f64>,
    dh_all: Vec<f64>,
    dpre_all: Vec<f64>,
    dh1_all: Vec<f64>,
    dv_all: Vec<f64>,
    upd: Vec<f64>,
}

impl NativeNet {
    pub fn new(shape: NetShape) -> NativeNet {
        let entries = shape.param_entries();
        let at = |name: &str| entries.iter().find(|e| e.name == name).unwrap().offset;
        let off = Offsets {
            pi_w1: at("pi_w1"),
            pi_b1: at("pi_b1"),
            pi_w2: at("pi_w2"),
            pi_b2: at("pi_b2"),
            pi_wh: at("pi_wh"),
            pi_bh: at("pi_bh"),
            vf_w1: at("vf_w1"),
            vf_b1: at("vf_b1"),
            vf_w2: at("vf_w2"),
            vf_b2: at("vf_b2"),
            vf_wh: at("vf_wh"),
            vf_bh: at("vf_bh"),
        };
        let slices = shape.head_slices();
        let param_count = shape.param_count();
        NativeNet {
            shape,
            slices,
            off,
            param_count,
            jobs: 1,
            scratch: RefCell::new(Scratch::default()),
        }
    }

    /// Enable data-parallel kernels: with `jobs > 1` (`0` = all pool
    /// workers, otherwise clamped to the pool's worker count),
    /// forward/backward/Adam shards run on the worker pool. Results are
    /// bitwise identical at every setting — `jobs` is purely a
    /// throughput knob.
    pub fn with_jobs(mut self, jobs: usize) -> NativeNet {
        self.jobs = if jobs == 1 { 1 } else { crate::util::pool::resolve_jobs(jobs) };
        self
    }

    /// The effective jobs setting (>= 1).
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Forward every row of `obs` into the scratch caches via the
    /// blocked dense kernels; `logp` gets the per-head log-softmax.
    /// Bitwise identical to the scalar per-row walk (`ScalarNet`): every
    /// output's reduction keeps ascending-`k` order, and the in-place
    /// log-softmax is the verbatim scalar loop.
    fn forward_cache(&self, params: &[f32], obs: &[f32], m: usize, s: &mut Scratch) {
        let (o, h, a) = (self.shape.obs_dim, self.shape.hidden, self.shape.act_total());
        let f = &self.off;
        s.h1p.resize(m * h, 0.0);
        s.h2p.resize(m * h, 0.0);
        s.logp.resize(m * a, 0.0);
        s.h1v.resize(m * h, 0.0);
        s.h2v.resize(m * h, 0.0);
        s.val.resize(m, 0.0);
        // policy trunk
        dense::matmul_bias_tanh(
            obs,
            m,
            o,
            &params[f.pi_w1..f.pi_w1 + o * h],
            &params[f.pi_b1..f.pi_b1 + h],
            h,
            &mut s.h1p,
        );
        dense::matmul_bias_tanh(
            &s.h1p,
            m,
            h,
            &params[f.pi_w2..f.pi_w2 + h * h],
            &params[f.pi_b2..f.pi_b2 + h],
            h,
            &mut s.h2p,
        );
        // logits -> per-head log-softmax
        dense::matmul_bias(
            &s.h2p,
            m,
            h,
            &params[f.pi_wh..f.pi_wh + h * a],
            &params[f.pi_bh..f.pi_bh + a],
            a,
            &mut s.logp,
        );
        for b in 0..m {
            let row = &mut s.logp[b * a..(b + 1) * a];
            for &(st, e) in &self.slices {
                let seg = &mut row[st..e];
                let max = seg.iter().fold(f32::NEG_INFINITY, |m2, &v| m2.max(v)) as f64;
                let lse = max + seg.iter().map(|&v| (v as f64 - max).exp()).sum::<f64>().ln();
                for v in seg.iter_mut() {
                    *v = (*v as f64 - lse) as f32;
                }
            }
        }
        // value trunk + width-1 head
        dense::matmul_bias_tanh(
            obs,
            m,
            o,
            &params[f.vf_w1..f.vf_w1 + o * h],
            &params[f.vf_b1..f.vf_b1 + h],
            h,
            &mut s.h1v,
        );
        dense::matmul_bias_tanh(
            &s.h1v,
            m,
            h,
            &params[f.vf_w2..f.vf_w2 + h * h],
            &params[f.vf_b2..f.vf_b2 + h],
            h,
            &mut s.h2v,
        );
        dense::matmul_bias(
            &s.h2v,
            m,
            h,
            &params[f.vf_wh..f.vf_wh + h],
            &params[f.vf_bh..f.vf_bh + 1],
            1,
            &mut s.val,
        );
    }

    /// [`NativeNet::forward_cache`] when `jobs > 1`: the same kernel
    /// sequence with the row-sharded `par_*` variants, plus a
    /// row-sharded log-softmax. Every output row is produced by exactly
    /// one shard running the serial op sequence, so the caches are
    /// bitwise identical to the serial fill.
    fn forward_cache_par(&self, params: &[f32], obs: &[f32], m: usize, s: &mut Scratch) {
        let (o, h, a) = (self.shape.obs_dim, self.shape.hidden, self.shape.act_total());
        let f = &self.off;
        let pool = crate::util::pool::global();
        s.h1p.resize(m * h, 0.0);
        s.h2p.resize(m * h, 0.0);
        s.logp.resize(m * a, 0.0);
        s.h1v.resize(m * h, 0.0);
        s.h2v.resize(m * h, 0.0);
        s.val.resize(m, 0.0);
        dense::par_matmul_bias_tanh(
            pool,
            obs,
            m,
            o,
            &params[f.pi_w1..f.pi_w1 + o * h],
            &params[f.pi_b1..f.pi_b1 + h],
            h,
            &mut s.h1p,
        );
        dense::par_matmul_bias_tanh(
            pool,
            &s.h1p,
            m,
            h,
            &params[f.pi_w2..f.pi_w2 + h * h],
            &params[f.pi_b2..f.pi_b2 + h],
            h,
            &mut s.h2p,
        );
        dense::par_matmul_bias(
            pool,
            &s.h2p,
            m,
            h,
            &params[f.pi_wh..f.pi_wh + h * a],
            &params[f.pi_bh..f.pi_bh + a],
            a,
            &mut s.logp,
        );
        // per-head log-softmax, sharded over rows (rows independent; the
        // per-row loop is verbatim the serial one)
        let slices = &self.slices;
        pool.scoped(|scope| {
            for logp_chunk in s.logp.chunks_mut(dense::PAR_ROW_SHARD * a) {
                scope.execute(move || {
                    for row in logp_chunk.chunks_mut(a) {
                        for &(st, e) in slices {
                            let seg = &mut row[st..e];
                            let max =
                                seg.iter().fold(f32::NEG_INFINITY, |m2, &v| m2.max(v)) as f64;
                            let lse = max
                                + seg.iter().map(|&v| (v as f64 - max).exp()).sum::<f64>().ln();
                            for v in seg.iter_mut() {
                                *v = (*v as f64 - lse) as f32;
                            }
                        }
                    }
                });
            }
        });
        dense::par_matmul_bias_tanh(
            pool,
            obs,
            m,
            o,
            &params[f.vf_w1..f.vf_w1 + o * h],
            &params[f.vf_b1..f.vf_b1 + h],
            h,
            &mut s.h1v,
        );
        dense::par_matmul_bias_tanh(
            pool,
            &s.h1v,
            m,
            h,
            &params[f.vf_w2..f.vf_w2 + h * h],
            &params[f.vf_b2..f.vf_b2 + h],
            h,
            &mut s.h2v,
        );
        dense::matmul_bias(
            &s.h2v,
            m,
            h,
            &params[f.vf_wh..f.vf_wh + h],
            &params[f.vf_bh..f.vf_bh + 1],
            1,
            &mut s.val,
        );
    }

    /// Serial or pool-sharded cache fill, by the `jobs` knob. Both paths
    /// are bitwise identical; small batches stay serial (shard overhead
    /// would dominate a `PAR_ROW_SHARD`-or-less forward).
    fn forward_cache_dispatch(&self, params: &[f32], obs: &[f32], m: usize, s: &mut Scratch) {
        if self.jobs > 1 && m > dense::PAR_ROW_SHARD {
            self.forward_cache_par(params, obs, m, s);
        } else {
            self.forward_cache(params, obs, m, s);
        }
    }

    /// Policy forward: per-head log-softmax + value for every
    /// observation row (the `runtime::Engine::policy_forward` shape).
    pub fn forward(&self, params: &[f32], obs: &[f32]) -> Result<ForwardOut> {
        let mut out = ForwardOut { logp_all: Vec::new(), value: Vec::new() };
        self.forward_into(params, obs, &mut out)?;
        Ok(out)
    }

    /// [`NativeNet::forward`] writing into a caller-owned `ForwardOut` —
    /// the rollout hot path reuses one output across every step, so the
    /// per-step forward allocates nothing in steady state.
    pub fn forward_into(&self, params: &[f32], obs: &[f32], out: &mut ForwardOut) -> Result<()> {
        ensure!(
            params.len() == self.param_count,
            "params len {} != {}",
            params.len(),
            self.param_count
        );
        ensure!(
            !obs.is_empty() && obs.len() % self.shape.obs_dim == 0,
            "obs len {} not a multiple of obs_dim {}",
            obs.len(),
            self.shape.obs_dim
        );
        let m = obs.len() / self.shape.obs_dim;
        let s = &mut *self.scratch.borrow_mut();
        self.forward_cache_dispatch(params, obs, m, s);
        out.logp_all.clear();
        out.logp_all.extend_from_slice(&s.logp);
        out.value.clear();
        out.value.extend_from_slice(&s.val);
        Ok(())
    }

    /// The SB3 PPO minibatch loss (forward only) — shared by the update
    /// (for its stats) and by the finite-difference gradient tests.
    #[allow(clippy::too_many_arguments)]
    pub fn ppo_loss(
        &self,
        params: &[f32],
        obs: &[f32],
        actions: &[i32],
        old_logp: &[f32],
        advantages: &[f32],
        returns: &[f32],
        hyper: [f32; 3],
    ) -> f32 {
        let m = old_logp.len();
        let a = self.shape.act_total();
        let s = &mut *self.scratch.borrow_mut();
        self.forward_cache_dispatch(params, obs, m, s);
        s.probs.resize(m * a, 0.0);
        s.dlp.resize(m, 0.0);
        s.lps.resize(m, 0.0);
        let Scratch { logp, val, probs, dlp, lps, .. } = s;
        let (loss, ..) = self.loss_terms(
            logp, val, actions, old_logp, advantages, returns, hyper, probs, dlp, lps,
        );
        loss as f32
    }

    /// Loss pieces over filled forward caches: (loss, pi_loss, vf_loss,
    /// entropy, approx_kl, clip_frac). Writes `probs[b·a + j] =
    /// exp(logp[b·a + j])` (exp'd once, shared with the backward pass),
    /// the per-row d loss/d joint-logp into `dlp`, and the per-row joint
    /// logp into `lps` — all pre-sized by the caller.
    #[allow(clippy::too_many_arguments)]
    fn loss_terms(
        &self,
        logp: &[f32],
        val: &[f32],
        actions: &[i32],
        old_logp: &[f32],
        advantages: &[f32],
        returns: &[f32],
        hyper: [f32; 3],
        probs: &mut [f64],
        dlp: &mut [f64],
        lps: &mut [f64],
    ) -> (f64, f64, f64, f64, f64, f64) {
        let m = old_logp.len();
        let a = self.shape.act_total();
        let nh = self.shape.n_heads();
        let (clip, ent_coef) = (hyper[1] as f64, hyper[2] as f64);

        // per-minibatch advantage normalization (SB3 normalize_advantage)
        let mean = advantages.iter().map(|&x| x as f64).sum::<f64>() / m as f64;
        let var = advantages.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / m as f64;
        let std = var.sqrt();

        let mut pi_loss = 0.0f64;
        let mut vf_loss = 0.0f64;
        let mut ent_sum = 0.0f64;
        let mut kl_sum = 0.0f64;
        let mut clipped = 0usize;
        for b in 0..m {
            let row = &logp[b * a..(b + 1) * a];
            let prow = &mut probs[b * a..(b + 1) * a];
            for (slot, &lp) in prow.iter_mut().zip(row.iter()) {
                *slot = (lp as f64).exp();
            }
            let mut lp = 0.0f64;
            for (h, &(s, _e)) in self.slices.iter().enumerate() {
                lp += row[s + actions[b * nh + h] as usize] as f64;
            }
            lps[b] = lp;
            let adv = (advantages[b] as f64 - mean) / (std + ADV_EPS);
            let log_ratio = lp - old_logp[b] as f64;
            let ratio = log_ratio.exp();
            let unclipped = adv * ratio;
            let cl = adv * ratio.clamp(1.0 - clip, 1.0 + clip);
            pi_loss -= unclipped.min(cl) / m as f64;
            // gradient of −min(unc, cl)/M w.r.t. lp: −adv·ratio/M through
            // whichever branch is active; the clipped branch saturates
            // (zero grad) exactly when it is the strict minimum.
            dlp[b] = if unclipped <= cl { -adv * ratio / m as f64 } else { 0.0 };
            if (ratio - 1.0).abs() > clip {
                clipped += 1;
            }
            kl_sum += ratio - 1.0 - log_ratio;
            vf_loss += (returns[b] as f64 - val[b] as f64).powi(2) / m as f64;
            // one definition of the MultiDiscrete entropy (same f64
            // accumulation order as the sampling-side statistics; exp
            // values reused from `probs`, bitwise the same products)
            ent_sum += categorical::entropy_from_probs(row, prow, &self.slices);
        }
        let entropy = ent_sum / m as f64;
        let loss = pi_loss + VF_COEF * vf_loss - ent_coef * entropy;
        (loss, pi_loss, vf_loss, entropy, kl_sum / m as f64, clipped as f64 / m as f64)
    }

    /// One PPO minibatch Adam step — the native twin of
    /// `runtime::Engine::ppo_update` (same inputs, same outputs, SB3
    /// semantics; see the module docs for the numerics caveat).
    #[allow(clippy::too_many_arguments)]
    pub fn ppo_update(
        &self,
        params: &[f32],
        adam_m: &[f32],
        adam_v: &[f32],
        step: f32,
        obs: &[f32],
        actions: &[i32],
        old_logp: &[f32],
        advantages: &[f32],
        returns: &[f32],
        hyper: [f32; 3],
    ) -> Result<UpdateOut> {
        let pc = self.param_count;
        ensure!(
            params.len() == pc && adam_m.len() == pc && adam_v.len() == pc,
            "param/adam vector length mismatch"
        );
        let m = old_logp.len();
        let (o, h, a, nh) =
            (self.shape.obs_dim, self.shape.hidden, self.shape.act_total(), self.shape.n_heads());
        ensure!(
            obs.len() == m * o
                && actions.len() == m * nh
                && advantages.len() == m
                && returns.len() == m,
            "minibatch shape mismatch (expected {m} rows)"
        );
        if self.jobs > 1 {
            return self.ppo_update_par(
                params, adam_m, adam_v, step, obs, actions, old_logp, advantages, returns, hyper,
            );
        }

        let s = &mut *self.scratch.borrow_mut();
        self.forward_cache(params, obs, m, s);
        s.probs.resize(m * a, 0.0);
        s.dlp.resize(m, 0.0);
        s.lps.resize(m, 0.0);
        s.dlogits.resize(a, 0.0);
        s.dh.resize(h, 0.0);
        s.dpre.resize(h, 0.0);
        s.grad.clear();
        s.grad.resize(pc, 0.0);
        let Scratch { h1p, h2p, logp, h1v, h2v, val, probs, dlp, lps, dlogits, dh, dpre, grad } =
            s;
        let (loss, pi_loss, vf_loss, entropy, approx_kl, clip_frac) = self.loss_terms(
            logp, val, actions, old_logp, advantages, returns, hyper, probs, dlp, lps,
        );
        let ent_coef = hyper[2] as f64;

        // ---- backward ----
        let f = &self.off;
        for b in 0..m {
            let row = &logp[b * a..(b + 1) * a];
            let prow = &probs[b * a..(b + 1) * a];
            // d loss / d logits: policy-gradient term + entropy bonus
            // (exp values reused from the loss pass)
            for (hd, &(st, e)) in self.slices.iter().enumerate() {
                let act = st + actions[b * nh + hd] as usize;
                let head_ent = categorical::entropy_from_probs(row, prow, &[(st, e)]);
                for j in st..e {
                    let p = prow[j];
                    let sel = if j == act { 1.0 } else { 0.0 };
                    dlogits[j] = dlp[b] * (sel - p)
                        + (ent_coef / m as f64) * p * (row[j] as f64 + head_ent);
                }
            }
            // policy head: dWh, dbh, dh2p — the blocked backward kernel
            let h2p_row = &h2p[b * h..(b + 1) * h];
            dense::grad_outer(
                h2p_row,
                dlogits,
                &params[f.pi_wh..f.pi_wh + h * a],
                &mut grad[f.pi_wh..f.pi_wh + h * a],
                a,
                dh,
            );
            for j in 0..a {
                grad[f.pi_bh + j] += dlogits[j] as f32;
            }
            // through tanh -> layer 2 -> layer 1
            Self::backprop_trunk(
                params, grad, f.pi_w1, f.pi_b1, f.pi_w2, f.pi_b2, o, h,
                &obs[b * o..(b + 1) * o],
                &h1p[b * h..(b + 1) * h],
                h2p_row,
                dh,
                dpre,
            );
            // value branch
            let dv = VF_COEF * 2.0 * (val[b] as f64 - returns[b] as f64) / m as f64;
            let h2v_row = &h2v[b * h..(b + 1) * h];
            for i in 0..h {
                grad[f.vf_wh + i] += (h2v_row[i] as f64 * dv) as f32;
                dh[i] = dv * params[f.vf_wh + i] as f64;
            }
            grad[f.vf_bh] += dv as f32;
            Self::backprop_trunk(
                params, grad, f.vf_w1, f.vf_b1, f.vf_w2, f.vf_b2, o, h,
                &obs[b * o..(b + 1) * o],
                &h1v[b * h..(b + 1) * h],
                h2v_row,
                dh,
                dpre,
            );
        }

        // global grad-norm clip, then the fused bias-corrected Adam step
        // (torch semantics, matches model.py) — one pass, no cloning
        let gnorm = adam::clip_global_norm(grad, MAX_GRAD_NORM);
        let lr = hyper[0] as f64;
        let (mut new_p, mut new_m, mut new_v) = (Vec::new(), Vec::new(), Vec::new());
        let upd_sq = adam::fused_step(
            params,
            adam_m,
            adam_v,
            grad,
            lr,
            ADAM_BETA1,
            ADAM_BETA2,
            ADAM_EPS,
            step as f64,
            &mut new_p,
            &mut new_m,
            &mut new_v,
        );

        Ok(UpdateOut {
            params: new_p,
            adam_m: new_m,
            adam_v: new_v,
            stats: UpdateStats {
                loss: loss as f32,
                pi_loss: pi_loss as f32,
                vf_loss: vf_loss as f32,
                entropy: entropy as f32,
                approx_kl: approx_kl as f32,
                clip_frac: clip_frac as f32,
                grad_norm: gnorm as f32,
                update_norm: upd_sq.sqrt() as f32,
            },
        })
    }

    /// The `jobs > 1` twin of [`NativeNet::ppo_update`]: the same update
    /// restructured into whole-minibatch phases so each phase can shard
    /// across the worker pool with fixed, output-disjoint geometry.
    ///
    /// Bit-identity to the serial path: the serial loop interleaves
    /// per-row head/trunk/value gradient work, but every gradient entry
    /// still receives its `m` adds in ascending-row order, and every f64
    /// reduction (`dh`, `dx`) is private to one (row, lane) pair.
    /// Phasing the loop over the whole minibatch preserves exactly those
    /// per-entry sequences, and the `par_*` kernels preserve them per
    /// shard — so params, Adam moments, and stats match the serial
    /// update bit for bit at any worker count
    /// (`tests/parallel_determinism.rs`).
    #[allow(clippy::too_many_arguments)]
    fn ppo_update_par(
        &self,
        params: &[f32],
        adam_m: &[f32],
        adam_v: &[f32],
        step: f32,
        obs: &[f32],
        actions: &[i32],
        old_logp: &[f32],
        advantages: &[f32],
        returns: &[f32],
        hyper: [f32; 3],
    ) -> Result<UpdateOut> {
        let pc = self.param_count;
        let m = old_logp.len();
        let (o, h, a, nh) =
            (self.shape.obs_dim, self.shape.hidden, self.shape.act_total(), self.shape.n_heads());
        let pool = crate::util::pool::global();
        let s = &mut *self.scratch.borrow_mut();
        self.forward_cache_dispatch(params, obs, m, s);
        s.probs.resize(m * a, 0.0);
        s.dlp.resize(m, 0.0);
        s.lps.resize(m, 0.0);
        s.dlogits_all.resize(m * a, 0.0);
        s.dh_all.resize(m * h, 0.0);
        s.dpre_all.resize(m * h, 0.0);
        s.dh1_all.resize(m * h, 0.0);
        s.dv_all.resize(m, 0.0);
        s.grad.clear();
        s.grad.resize(pc, 0.0);
        let Scratch {
            h1p,
            h2p,
            logp,
            h1v,
            h2v,
            val,
            probs,
            dlp,
            lps,
            dlogits_all,
            dh_all,
            dpre_all,
            dh1_all,
            dv_all,
            grad,
            upd,
            ..
        } = s;
        let (loss, pi_loss, vf_loss, entropy, approx_kl, clip_frac) = self.loss_terms(
            logp, val, actions, old_logp, advantages, returns, hyper, probs, dlp, lps,
        );
        let ent_coef = hyper[2] as f64;
        let f = &self.off;

        // read-only views for the pool tasks
        let (logp, probs, dlp) = (&logp[..], &probs[..], &dlp[..]);
        let (h1p, h2p, h1v, h2v, val) = (&h1p[..], &h2p[..], &h1v[..], &h2v[..], &val[..]);
        let slices = &self.slices;

        // phase 1 — d loss / d logits for the whole minibatch, sharded
        // over rows (rows are independent; the per-row loop is verbatim
        // the serial one)
        pool.scoped(|scope| {
            for (rb, dl_chunk) in dlogits_all.chunks_mut(dense::PAR_ROW_SHARD * a).enumerate() {
                let b0 = rb * dense::PAR_ROW_SHARD;
                scope.execute(move || {
                    for (bi, dlrow) in dl_chunk.chunks_mut(a).enumerate() {
                        let b = b0 + bi;
                        let row = &logp[b * a..(b + 1) * a];
                        let prow = &probs[b * a..(b + 1) * a];
                        for (hd, &(st, e)) in slices.iter().enumerate() {
                            let act = st + actions[b * nh + hd] as usize;
                            let head_ent =
                                categorical::entropy_from_probs(row, prow, &[(st, e)]);
                            for j in st..e {
                                let p = prow[j];
                                let sel = if j == act { 1.0 } else { 0.0 };
                                dlrow[j] = dlp[b] * (sel - p)
                                    + (ent_coef / m as f64) * p * (row[j] as f64 + head_ent);
                            }
                        }
                    }
                });
            }
        });
        let dlogits_all = &dlogits_all[..];

        // phase 2 — policy head: weight grads + dh2 (lane-sharded
        // batched kernel), bias grads (column-sharded); each entry gets
        // its adds in ascending-row order, as the serial loop did
        dense::par_grad_outer_batch(
            pool,
            h2p,
            m,
            h,
            dlogits_all,
            &params[f.pi_wh..f.pi_wh + h * a],
            &mut grad[f.pi_wh..f.pi_wh + h * a],
            a,
            dh_all,
        );
        dense::par_bias_accum(pool, dlogits_all, m, a, &mut grad[f.pi_bh..f.pi_bh + a]);

        // phase 3 — policy trunk. The tanh backward is elementwise (one
        // independent write per entry) and cheap: it stays inline.
        for (dp, (&dh2, &act)) in dpre_all.iter_mut().zip(dh_all.iter().zip(h2p.iter())) {
            *dp = dh2 * (1.0 - (act as f64).powi(2));
        }
        dense::par_bias_accum(pool, &dpre_all[..], m, h, &mut grad[f.pi_b2..f.pi_b2 + h]);
        dense::par_grad_outer_batch(
            pool,
            h1p,
            m,
            h,
            &dpre_all[..],
            &params[f.pi_w2..f.pi_w2 + h * h],
            &mut grad[f.pi_w2..f.pi_w2 + h * h],
            h,
            dh1_all,
        );
        for (dp, (&dh1, &act)) in dpre_all.iter_mut().zip(dh1_all.iter().zip(h1p.iter())) {
            *dp = dh1 * (1.0 - (act as f64).powi(2));
        }
        dense::par_bias_accum(pool, &dpre_all[..], m, h, &mut grad[f.pi_b1..f.pi_b1 + h]);
        dense::par_grad_outer_weights_batch(
            pool,
            obs,
            m,
            o,
            &dpre_all[..],
            &mut grad[f.pi_w1..f.pi_w1 + o * h],
            h,
        );

        // phase 4 — value branch. The width-1 head is m·hidden work:
        // inline, in the serial loop's per-entry order.
        for (dv, (&v, &r)) in dv_all.iter_mut().zip(val.iter().zip(returns.iter())) {
            *dv = VF_COEF * 2.0 * (v as f64 - r as f64) / m as f64;
        }
        for b in 0..m {
            let dv = dv_all[b];
            let h2v_row = &h2v[b * h..(b + 1) * h];
            for i in 0..h {
                grad[f.vf_wh + i] += (h2v_row[i] as f64 * dv) as f32;
            }
            grad[f.vf_bh] += dv as f32;
        }
        for b in 0..m {
            let dv = dv_all[b];
            for (i, dst) in dh_all[b * h..(b + 1) * h].iter_mut().enumerate() {
                *dst = dv * params[f.vf_wh + i] as f64;
            }
        }
        for (dp, (&dhv, &act)) in dpre_all.iter_mut().zip(dh_all.iter().zip(h2v.iter())) {
            *dp = dhv * (1.0 - (act as f64).powi(2));
        }
        dense::par_bias_accum(pool, &dpre_all[..], m, h, &mut grad[f.vf_b2..f.vf_b2 + h]);
        dense::par_grad_outer_batch(
            pool,
            h1v,
            m,
            h,
            &dpre_all[..],
            &params[f.vf_w2..f.vf_w2 + h * h],
            &mut grad[f.vf_w2..f.vf_w2 + h * h],
            h,
            dh1_all,
        );
        for (dp, (&dh1, &act)) in dpre_all.iter_mut().zip(dh1_all.iter().zip(h1v.iter())) {
            *dp = dh1 * (1.0 - (act as f64).powi(2));
        }
        dense::par_bias_accum(pool, &dpre_all[..], m, h, &mut grad[f.vf_b1..f.vf_b1 + h]);
        dense::par_grad_outer_weights_batch(
            pool,
            obs,
            m,
            o,
            &dpre_all[..],
            &mut grad[f.vf_w1..f.vf_w1 + o * h],
            h,
        );

        // clip stays serial (one global ascending-index reduction), Adam
        // shards per-entry math and reduces Σ update² serially
        let gnorm = adam::clip_global_norm(grad, MAX_GRAD_NORM);
        let lr = hyper[0] as f64;
        let (mut new_p, mut new_m, mut new_v) = (Vec::new(), Vec::new(), Vec::new());
        let upd_sq = adam::par_fused_step(
            pool,
            params,
            adam_m,
            adam_v,
            grad,
            lr,
            ADAM_BETA1,
            ADAM_BETA2,
            ADAM_EPS,
            step as f64,
            &mut new_p,
            &mut new_m,
            &mut new_v,
            upd,
        );

        Ok(UpdateOut {
            params: new_p,
            adam_m: new_m,
            adam_v: new_v,
            stats: UpdateStats {
                loss: loss as f32,
                pi_loss: pi_loss as f32,
                vf_loss: vf_loss as f32,
                entropy: entropy as f32,
                approx_kl: approx_kl as f32,
                clip_frac: clip_frac as f32,
                grad_norm: gnorm as f32,
                update_norm: upd_sq.sqrt() as f32,
            },
        })
    }

    /// Backprop a two-layer tanh trunk given `dh` = dL/d(layer-2
    /// activation); accumulates weight/bias grads and scratches `dh`.
    #[allow(clippy::too_many_arguments)]
    fn backprop_trunk(
        params: &[f32],
        grad: &mut [f32],
        w1: usize,
        b1: usize,
        w2: usize,
        b2: usize,
        o: usize,
        h: usize,
        x: &[f32],
        h1: &[f32],
        h2: &[f32],
        dh: &mut [f64],
        dpre: &mut [f64],
    ) {
        // layer 2: pre-activation grad, then the blocked outer-product
        // kernel for weights + dh1
        for j in 0..h {
            dpre[j] = dh[j] * (1.0 - (h2[j] as f64).powi(2));
            grad[b2 + j] += dpre[j] as f32;
        }
        dense::grad_outer(h1, dpre, &params[w2..w2 + h * h], &mut grad[w2..w2 + h * h], h, dh);
        // layer 1: no upstream, weights only
        for j in 0..h {
            dpre[j] = dh[j] * (1.0 - (h1[j] as f64).powi(2));
            grad[b1 + j] += dpre[j] as f32;
        }
        dense::grad_outer_weights(x, dpre, &mut grad[w1..w1 + o * h], h);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::space::DesignSpace;
    use crate::rl::init::init_param_entries;
    use crate::util::Rng;

    fn tiny_shape() -> NetShape {
        // small trunk, two heads — big enough to exercise every tensor
        NetShape { obs_dim: 3, hidden: 4, dims: vec![2, 3] }
    }

    fn init(shape: &NetShape, seed: u64) -> Vec<f32> {
        init_param_entries(&shape.param_entries(), shape.param_count(), seed)
    }

    #[test]
    fn shape_mirrors_model_py_layout() {
        let layout = DesignSpace::case_i().layout();
        let s = NetShape::for_layout(&layout);
        assert_eq!(s.obs_dim, crate::gym::OBS_DIM);
        assert_eq!(s.act_total(), 591);
        // model.py: 10·64 + 64 + 64·64 + 64 + 64·591 + 591 (policy)
        //         + 10·64 + 64 + 64·64 + 64 + 64 + 1      (value)
        let pi = 10 * 64 + 64 + 64 * 64 + 64 + 64 * 591 + 591;
        let vf = 10 * 64 + 64 + 64 * 64 + 64 + 64 + 1;
        assert_eq!(s.param_count(), pi + vf);
        let entries = s.param_entries();
        assert_eq!(entries[0].name, "pi_w1");
        assert_eq!(entries[11].name, "vf_bh");
        let mut off = 0;
        for e in &entries {
            assert_eq!(e.offset, off);
            assert_eq!(e.size, e.shape.iter().product::<usize>());
            off += e.size;
        }
        // the placement head adds PLACEMENT_HEAD_DIM logits everywhere
        let learned = NetShape::for_layout(&DesignSpace::case_i().with_placement_head().layout());
        assert_eq!(learned.act_total(), 595);
        assert_eq!(learned.param_count() - s.param_count(), 4 * 64 + 4);
    }

    #[test]
    fn zero_params_forward_is_uniform_with_zero_value() {
        let shape = tiny_shape();
        let net = NativeNet::new(shape.clone());
        let params = vec![0f32; shape.param_count()];
        let out = net.forward(&params, &[0.3, -0.1, 0.8]).unwrap();
        assert_eq!(out.value, vec![0.0]);
        // zero logits -> uniform per head: [-ln2, -ln2, -ln3, -ln3, -ln3]
        let want = [2f32, 2.0, 3.0, 3.0, 3.0].map(|d| -d.ln());
        for (got, want) in out.logp_all.iter().zip(want.iter()) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
    }

    #[test]
    fn bias_only_head_matches_hand_log_softmax() {
        let shape = tiny_shape();
        let net = NativeNet::new(shape.clone());
        let mut params = vec![0f32; shape.param_count()];
        // pi_bh lives after the trunk tensors; look it up via entries
        let bh = shape.param_entries().iter().find(|e| e.name == "pi_bh").unwrap().offset;
        params[bh] = 1.0; // head 0 logits [1, 0]
        let out = net.forward(&params, &[0.0, 0.0, 0.0]).unwrap();
        let z = 1f64.exp() + 1.0;
        assert!((out.logp_all[0] as f64 - (1.0 - z.ln())).abs() < 1e-6);
        assert!((out.logp_all[1] as f64 - (-z.ln())).abs() < 1e-6);
        // head 1 stays uniform and each head sums to probability one
        for seg in [&out.logp_all[0..2], &out.logp_all[2..5]] {
            let p: f64 = seg.iter().map(|&lp| (lp as f64).exp()).sum();
            assert!((p - 1.0).abs() < 1e-6);
        }
    }

    /// A random but consistent minibatch over the tiny net.
    fn batch(shape: &NetShape, m: usize, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let obs: Vec<f32> = (0..m * shape.obs_dim).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let actions: Vec<i32> = (0..m)
            .flat_map(|_| shape.dims.iter().map(|&d| rng.below(d as u64) as i32).collect::<Vec<_>>())
            .collect();
        let old_logp: Vec<f32> = (0..m).map(|_| rng.range_f64(-3.0, -1.0) as f32).collect();
        let adv: Vec<f32> = (0..m).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect();
        let ret: Vec<f32> = (0..m).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        (obs, actions, old_logp, adv, ret)
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let shape = tiny_shape();
        let net = NativeNet::new(shape.clone());
        let params = init(&shape, 3);
        let (obs, actions, old_logp, adv, ret) = batch(&shape, 8, 4);
        let hyper = [1e-3f32, 0.2, 0.05];

        // recover the pre-clip gradient from one Adam step at t=1:
        // m̂ = g, v̂ = g² -> update = lr·sign(g)·|g|/(|g|+eps) — not
        // invertible cleanly, so instead check the *loss* against
        // central differences coordinate by coordinate on a sample.
        let loss =
            |p: &[f32]| net.ppo_loss(p, &obs, &actions, &old_logp, &adv, &ret, hyper) as f64;
        let zeros = vec![0.0f32; params.len()];
        let out = net
            .ppo_update(&params, &zeros, &zeros, 1.0, &obs, &actions, &old_logp, &adv, &ret, hyper)
            .unwrap();
        // reconstruct the clipped gradient direction from the Adam step:
        // at t=1, update_i = lr·g_i/(|g_i| + eps) so sign(update) == sign(g).
        let mut checked = 0;
        let eps = 1e-2f32;
        let mut rng = Rng::new(9);
        for _ in 0..200 {
            let i = rng.below(params.len() as u64) as usize;
            let mut up = params.clone();
            up[i] += eps;
            let mut dn = params.clone();
            dn[i] -= eps;
            let fd = (loss(&up) - loss(&dn)) / (2.0 * eps as f64);
            if fd.abs() < 5e-3 {
                continue; // below FD noise floor for f32 losses
            }
            let step = params[i] as f64 - out.params[i] as f64; // lr-scaled, sign(g)
            assert!(
                fd * step > 0.0,
                "param {i}: finite-difference grad {fd:+.5} disagrees with update {step:+.7}"
            );
            checked += 1;
        }
        assert!(checked >= 10, "only {checked} coordinates above the FD noise floor");
    }

    #[test]
    fn uniform_advantages_leave_policy_untouched() {
        // adv normalization zeroes constant advantages and ent_coef 0
        // removes the entropy bonus -> the policy branch has zero
        // gradient; only the value branch moves.
        let shape = tiny_shape();
        let net = NativeNet::new(shape.clone());
        let params = init(&shape, 5);
        let (obs, actions, old_logp, _adv, ret) = batch(&shape, 6, 6);
        let adv = vec![1.5f32; 6];
        let hyper = [1e-3f32, 0.2, 0.0];
        let zeros = vec![0.0f32; params.len()];
        let out = net
            .ppo_update(&params, &zeros, &zeros, 1.0, &obs, &actions, &old_logp, &adv, &ret, hyper)
            .unwrap();
        let entries = shape.param_entries();
        let vf_w1 = entries.iter().find(|e| e.name == "vf_w1").unwrap().offset;
        assert_eq!(params[..vf_w1], out.params[..vf_w1], "policy params must not move");
        assert_ne!(params[vf_w1..], out.params[vf_w1..], "value params must move");
    }

    #[test]
    fn repeated_updates_reduce_value_loss() {
        let shape = tiny_shape();
        let net = NativeNet::new(shape.clone());
        let mut params = init(&shape, 7);
        let mut m = vec![0f32; params.len()];
        let mut v = vec![0f32; params.len()];
        let (obs, actions, old_logp, adv, ret) = batch(&shape, 16, 8);
        let hyper = [3e-3f32, 0.2, 0.0];
        let mut first = None;
        let mut last = None;
        for t in 1..=60 {
            let out = net
                .ppo_update(&params, &m, &v, t as f32, &obs, &actions, &old_logp, &adv, &ret, hyper)
                .unwrap();
            params = out.params;
            m = out.adam_m;
            v = out.adam_v;
            if first.is_none() {
                first = Some(out.stats.vf_loss);
            }
            last = Some(out.stats.vf_loss);
            assert!(out.stats.loss.is_finite());
            assert!(out.stats.grad_norm.is_finite());
        }
        assert!(
            last.unwrap() < first.unwrap(),
            "value loss did not improve: {} -> {}",
            first.unwrap(),
            last.unwrap()
        );
    }

    #[test]
    fn grad_norm_is_clipped() {
        let shape = tiny_shape();
        let net = NativeNet::new(shape.clone());
        let params = init(&shape, 11);
        let (obs, actions, old_logp, _adv, _ret) = batch(&shape, 8, 12);
        // huge advantages and returns to force a big raw gradient
        let adv: Vec<f32> = (0..8).map(|i| if i % 2 == 0 { 1e3 } else { -1e3 }).collect();
        let ret = vec![50f32; 8];
        let zeros = vec![0.0f32; params.len()];
        let out = net
            .ppo_update(&params, &zeros, &zeros, 1.0, &obs, &actions, &old_logp, &adv, &ret, [
                1e-3, 0.2, 0.0,
            ])
            .unwrap();
        assert!(
            out.stats.grad_norm > MAX_GRAD_NORM as f32,
            "test needs an above-cap raw gradient, got {}",
            out.stats.grad_norm
        );
        // the applied update reflects the clipped gradient: with t=1 and
        // Adam bias correction, |update_i| <= lr, so the update norm is
        // bounded by lr·sqrt(P) regardless of the raw norm.
        let bound = 1e-3 * (params.len() as f64).sqrt();
        assert!((out.stats.update_norm as f64) <= bound * 1.001);
    }
}

//! Rollout buffer with Generalized Advantage Estimation (SB3 semantics).
//!
//! Stores one on-policy batch of `n_steps` transitions, then computes
//! GAE(γ, λ) advantages and returns. Matches SB3's `RolloutBuffer`:
//! `delta = r + γ·V(s') ·(1−done) − V(s)`,
//! `adv = delta + γλ·(1−done)·adv'`, `ret = adv + V(s)`.

use crate::gym::{Step, OBS_DIM};

/// One on-policy rollout batch, sized at runtime from the action
/// layout's head count (`DesignSpace::layout().n_heads()`) — 14 for the
/// Table 1 space, 15 with the learned-placement head.
#[derive(Clone, Debug)]
pub struct RolloutBuffer {
    pub n_steps: usize,
    /// Heads per action (row width of `actions`).
    pub n_heads: usize,
    pub obs: Vec<f32>,        // n_steps × OBS_DIM
    pub actions: Vec<i32>,    // n_steps × n_heads
    pub log_probs: Vec<f32>,  // n_steps
    pub rewards: Vec<f64>,    // n_steps (raw env scale)
    pub values: Vec<f32>,     // n_steps
    pub dones: Vec<bool>,     // n_steps (episode ended AFTER this step)
    pub advantages: Vec<f32>, // n_steps
    pub returns: Vec<f32>,    // n_steps
    pos: usize,
    /// Env count of the in-progress batched fill (0 = none / plain
    /// `push`); pins K across one rollout so a mixed-K call sequence
    /// panics instead of corrupting the env-major layout.
    batch_k: usize,
}

impl RolloutBuffer {
    pub fn new(n_steps: usize, n_heads: usize) -> RolloutBuffer {
        assert!(n_heads >= 1, "rollout rows need at least one action head");
        RolloutBuffer {
            n_steps,
            n_heads,
            obs: vec![0.0; n_steps * OBS_DIM],
            actions: vec![0; n_steps * n_heads],
            log_probs: vec![0.0; n_steps],
            rewards: vec![0.0; n_steps],
            values: vec![0.0; n_steps],
            dones: vec![false; n_steps],
            advantages: vec![0.0; n_steps],
            returns: vec![0.0; n_steps],
            pos: 0,
            batch_k: 0,
        }
    }

    pub fn clear(&mut self) {
        self.pos = 0;
        self.batch_k = 0;
    }

    pub fn is_full(&self) -> bool {
        self.pos == self.n_steps
    }

    pub fn len(&self) -> usize {
        self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.pos == 0
    }

    /// Append one transition.
    pub fn push(
        &mut self,
        obs: &[f32; OBS_DIM],
        action: &[usize],
        log_prob: f64,
        reward: f64,
        value: f32,
        done: bool,
    ) {
        assert_eq!(self.batch_k, 0, "do not mix push with push_step_batch");
        assert!(self.pos < self.n_steps, "rollout buffer overflow");
        assert_eq!(action.len(), self.n_heads, "action arity != buffer row width");
        let o = self.pos * OBS_DIM;
        self.obs[o..o + OBS_DIM].copy_from_slice(obs);
        let a = self.pos * self.n_heads;
        for (i, &x) in action.iter().enumerate() {
            self.actions[a + i] = x as i32;
        }
        self.log_probs[self.pos] = log_prob as f32;
        self.rewards[self.pos] = reward;
        self.values[self.pos] = value;
        self.dones[self.pos] = done;
        self.pos += 1;
    }

    /// Record the `t`-th transition of every environment from one
    /// [`crate::gym::VecEnv::step_batch`] call. The buffer is laid out
    /// **env-major**: env `e`'s trajectory occupies the contiguous rows
    /// `[e*T, (e+1)*T)` with `T = n_steps / K`, so the GAE recursion in
    /// [`RolloutBuffer::compute_gae_batched`] never crosses an env
    /// boundary. `obs` holds the K pre-step observations (K x OBS_DIM,
    /// the layout [`crate::gym::VecEnv::write_obs_flat`] produces).
    ///
    /// Must be called with `t = 0, 1, 2, ...` in order and a fixed K;
    /// do not mix with [`RolloutBuffer::push`].
    pub fn push_step_batch<A: AsRef<[usize]>>(
        &mut self,
        t: usize,
        obs: &[f32],
        actions: &[A],
        log_probs: &[f64],
        values: &[f32],
        steps: &[Step],
    ) {
        let k = steps.len();
        assert!(k >= 1, "push_step_batch with zero envs");
        assert_eq!(
            self.n_steps % k,
            0,
            "n_steps {} not divisible by {k} envs",
            self.n_steps
        );
        if self.pos == 0 {
            self.batch_k = k;
        } else {
            assert_eq!(self.batch_k, k, "push_step_batch K changed mid-rollout");
        }
        assert_eq!(obs.len(), k * OBS_DIM);
        assert_eq!(actions.len(), k);
        assert_eq!(log_probs.len(), k);
        assert_eq!(values.len(), k);
        assert_eq!(t * k, self.pos, "push_step_batch calls must be in order");
        assert!(self.pos + k <= self.n_steps, "rollout buffer overflow");
        let t_len = self.n_steps / k;
        for e in 0..k {
            let row = e * t_len + t;
            let o = row * OBS_DIM;
            self.obs[o..o + OBS_DIM].copy_from_slice(&obs[e * OBS_DIM..(e + 1) * OBS_DIM]);
            let action = actions[e].as_ref();
            assert_eq!(action.len(), self.n_heads, "action arity != buffer row width");
            let a = row * self.n_heads;
            for (i, &x) in action.iter().enumerate() {
                self.actions[a + i] = x as i32;
            }
            self.log_probs[row] = log_probs[e] as f32;
            self.rewards[row] = steps[e].reward;
            self.values[row] = values[e];
            self.dones[row] = steps[e].done;
        }
        self.pos += k;
    }

    /// Compute GAE advantages and returns. `last_value` bootstraps the
    /// final state; `reward_scale` maps raw env rewards into the network's
    /// value range (SB3 users typically wrap the env — we divide here).
    pub fn compute_gae(&mut self, last_value: f32, gamma: f64, lam: f64, reward_scale: f64) {
        self.compute_gae_batched(&[last_value], gamma, lam, reward_scale);
    }

    /// GAE over a K-env, env-major buffer (the layout
    /// [`RolloutBuffer::push_step_batch`] writes): the recursion runs
    /// independently over each env's contiguous `n_steps / K` rows,
    /// bootstrapped by that env's entry in `last_values`. With K = 1 this
    /// is exactly the classic single-env scan.
    pub fn compute_gae_batched(
        &mut self,
        last_values: &[f32],
        gamma: f64,
        lam: f64,
        reward_scale: f64,
    ) {
        assert!(self.is_full(), "compute_gae on partial rollout");
        let k = last_values.len();
        assert!(k >= 1, "compute_gae_batched with zero envs");
        assert!(
            (self.batch_k == 0 && k == 1) || self.batch_k == k,
            "GAE env count {k} does not match the buffer's fill layout ({})",
            self.batch_k
        );
        assert_eq!(
            self.n_steps % k,
            0,
            "n_steps {} not divisible by {k} envs",
            self.n_steps
        );
        let t_len = self.n_steps / k;
        for (e, &last_value) in last_values.iter().enumerate() {
            let base = e * t_len;
            let mut adv = 0.0f64;
            for i in (0..t_len).rev() {
                let t = base + i;
                let non_terminal = if self.dones[t] { 0.0 } else { 1.0 };
                let next_value = if i + 1 < t_len {
                    if self.dones[t] { 0.0 } else { self.values[t + 1] as f64 }
                } else {
                    non_terminal * last_value as f64
                };
                let r = self.rewards[t] / reward_scale;
                let delta = r + gamma * next_value - self.values[t] as f64;
                adv = delta + gamma * lam * non_terminal * adv;
                self.advantages[t] = adv as f32;
                self.returns[t] = (adv + self.values[t] as f64) as f32;
            }
        }
    }

    /// Gather a minibatch by index list into the provided scratch arrays.
    #[allow(clippy::too_many_arguments)]
    pub fn gather(
        &self,
        idx: &[usize],
        obs: &mut [f32],
        actions: &mut [i32],
        log_probs: &mut [f32],
        advantages: &mut [f32],
        returns: &mut [f32],
    ) {
        let nh = self.n_heads;
        for (row, &i) in idx.iter().enumerate() {
            obs[row * OBS_DIM..(row + 1) * OBS_DIM]
                .copy_from_slice(&self.obs[i * OBS_DIM..(i + 1) * OBS_DIM]);
            actions[row * nh..(row + 1) * nh]
                .copy_from_slice(&self.actions[i * nh..(i + 1) * nh]);
            log_probs[row] = self.log_probs[i];
            advantages[row] = self.advantages[i];
            returns[row] = self.returns[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::space::N_HEADS;

    fn filled(n: usize, rewards: &[f64], values: &[f32], dones: &[bool]) -> RolloutBuffer {
        let mut b = RolloutBuffer::new(n, N_HEADS);
        for t in 0..n {
            b.push(
                &[0.0; OBS_DIM],
                &[0usize; N_HEADS],
                -1.0,
                rewards[t],
                values[t],
                dones[t],
            );
        }
        b
    }

    #[test]
    fn gae_matches_hand_computation_no_done() {
        // 2 steps, no terminal: standard recursive GAE.
        let mut b = filled(2, &[1.0, 1.0], &[0.5, 0.5], &[false, false]);
        let (g, l, last_v) = (0.99, 0.95, 0.5f32);
        b.compute_gae(last_v, g, l, 1.0);
        let d1 = 1.0 + g * 0.5 - 0.5;
        let a1 = d1;
        let d0 = 1.0 + g * 0.5 - 0.5;
        let a0 = d0 + g * l * a1;
        assert!((b.advantages[1] as f64 - a1).abs() < 1e-6);
        assert!((b.advantages[0] as f64 - a0).abs() < 1e-6);
        assert!((b.returns[0] as f64 - (a0 + 0.5)).abs() < 1e-6);
    }

    #[test]
    fn done_cuts_bootstrap() {
        // terminal at t=0: its advantage ignores V(s1).
        let mut b = filled(2, &[2.0, 0.0], &[0.5, 9.0], &[true, false]);
        b.compute_gae(9.0, 0.99, 0.95, 1.0);
        let a0 = 2.0 - 0.5; // no next value, no propagation from t=1
        assert!((b.advantages[0] as f64 - a0).abs() < 1e-6);
    }

    #[test]
    fn terminal_last_step_ignores_last_value() {
        let mut b = filled(1, &[1.0], &[0.0], &[true]);
        b.compute_gae(100.0, 0.99, 0.95, 1.0);
        assert!((b.advantages[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn reward_scale_divides() {
        let mut a = filled(1, &[100.0], &[0.0], &[true]);
        a.compute_gae(0.0, 0.99, 0.95, 100.0);
        assert!((a.advantages[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gather_permutes_rows() {
        let mut b = RolloutBuffer::new(3, N_HEADS);
        for t in 0..3 {
            let mut obs = [0f32; OBS_DIM];
            obs[0] = t as f32;
            let mut act = [0usize; N_HEADS];
            act[0] = t;
            b.push(&obs, &act, -(t as f64), t as f64, t as f32, false);
        }
        b.compute_gae(0.0, 0.99, 0.95, 1.0);
        let idx = [2usize, 0];
        let mut obs = vec![0f32; 2 * OBS_DIM];
        let mut actions = vec![0i32; 2 * N_HEADS];
        let mut lp = vec![0f32; 2];
        let mut adv = vec![0f32; 2];
        let mut ret = vec![0f32; 2];
        b.gather(&idx, &mut obs, &mut actions, &mut lp, &mut adv, &mut ret);
        assert_eq!(obs[0], 2.0);
        assert_eq!(obs[OBS_DIM], 0.0);
        assert_eq!(actions[0], 2);
        assert_eq!(lp[0], -2.0);
    }

    fn dummy_step(reward: f64, done: bool, obs0: f32) -> Step {
        use crate::cost::{evaluate, Calib};
        use crate::model::space::DesignSpace;
        let space = DesignSpace::case_i();
        let eval = evaluate(&Calib::default(), &space.decode(&[0usize; N_HEADS]));
        let mut obs = [0f32; OBS_DIM];
        obs[0] = obs0;
        Step { obs, reward, done, eval }
    }

    #[test]
    fn batched_fill_and_gae_match_per_env_buffers() {
        // 2 envs x 3 steps: the env-major batched buffer must reproduce
        // two independently-filled single-env buffers exactly.
        let k = 2usize;
        let t_len = 3usize;
        let rewards = [[1.0f64, 2.0, 3.0], [4.0, 5.0, 6.0]];
        let values = [[0.1f32, 0.2, 0.3], [0.4, 0.5, 0.6]];
        let dones = [[false, true, false], [false, false, true]];
        let last_values = [0.7f32, 0.8];

        let mut batched = RolloutBuffer::new(k * t_len, N_HEADS);
        for t in 0..t_len {
            let mut obs_flat = vec![0f32; k * OBS_DIM];
            let mut actions = vec![[0usize; N_HEADS]; k];
            let mut lps = vec![0f64; k];
            let mut vals = vec![0f32; k];
            let mut steps = Vec::new();
            for e in 0..k {
                obs_flat[e * OBS_DIM] = (10 * e + t) as f32;
                actions[e][0] = e + t;
                lps[e] = -((e + t) as f64);
                vals[e] = values[e][t];
                steps.push(dummy_step(rewards[e][t], dones[e][t], 0.0));
            }
            batched.push_step_batch(t, &obs_flat, &actions, &lps, &vals, &steps);
        }
        assert!(batched.is_full());
        batched.compute_gae_batched(&last_values, 0.99, 0.95, 1.0);

        for e in 0..k {
            let mut solo = RolloutBuffer::new(t_len, N_HEADS);
            for t in 0..t_len {
                let mut obs = [0f32; OBS_DIM];
                obs[0] = (10 * e + t) as f32;
                let mut act = [0usize; N_HEADS];
                act[0] = e + t;
                solo.push(&obs, &act, -((e + t) as f64), rewards[e][t], values[e][t], dones[e][t]);
            }
            solo.compute_gae(last_values[e], 0.99, 0.95, 1.0);
            for t in 0..t_len {
                let row = e * t_len + t;
                assert_eq!(
                    batched.obs[row * OBS_DIM..(row + 1) * OBS_DIM],
                    solo.obs[t * OBS_DIM..(t + 1) * OBS_DIM]
                );
                assert_eq!(
                    batched.actions[row * N_HEADS..(row + 1) * N_HEADS],
                    solo.actions[t * N_HEADS..(t + 1) * N_HEADS]
                );
                assert_eq!(batched.log_probs[row], solo.log_probs[t]);
                assert_eq!(batched.rewards[row], solo.rewards[t]);
                assert_eq!(batched.dones[row], solo.dones[t]);
                assert_eq!(batched.advantages[row], solo.advantages[t]);
                assert_eq!(batched.returns[row], solo.returns[t]);
            }
        }
    }

    #[test]
    fn single_env_batched_gae_equals_classic() {
        let mut a = filled(3, &[1.0, 2.0, 3.0], &[0.5, 0.4, 0.3], &[false, true, false]);
        let mut b = filled(3, &[1.0, 2.0, 3.0], &[0.5, 0.4, 0.3], &[false, true, false]);
        a.compute_gae(0.9, 0.99, 0.95, 100.0);
        b.compute_gae_batched(&[0.9], 0.99, 0.95, 100.0);
        assert_eq!(a.advantages, b.advantages);
        assert_eq!(a.returns, b.returns);
    }

    #[test]
    #[should_panic(expected = "K changed mid-rollout")]
    fn mixed_k_batched_push_panics() {
        // n_steps=12: k=4 then k=2 would silently scramble the env-major
        // layout without the batch_k pin (t*k == pos alone passes).
        let mut b = RolloutBuffer::new(12, N_HEADS);
        let push = |b: &mut RolloutBuffer, t: usize, k: usize| {
            let obs = vec![0f32; k * OBS_DIM];
            let actions = vec![[0usize; N_HEADS]; k];
            let steps: Vec<Step> = (0..k).map(|_| dummy_step(0.0, false, 0.0)).collect();
            b.push_step_batch(t, &obs, &actions, &vec![0.0; k], &vec![0f32; k], &steps);
        };
        push(&mut b, 0, 4);
        push(&mut b, 2, 2); // t*k == pos, but K changed
    }

    #[test]
    #[should_panic(expected = "do not mix push")]
    fn mixing_push_and_batched_push_panics() {
        let mut b = RolloutBuffer::new(4, N_HEADS);
        let obs = vec![0f32; 2 * OBS_DIM];
        let actions = vec![[0usize; N_HEADS]; 2];
        let steps = vec![dummy_step(0.0, false, 0.0), dummy_step(0.0, false, 0.0)];
        b.push_step_batch(0, &obs, &actions, &[0.0, 0.0], &[0.0, 0.0], &steps);
        b.push(&[0.0; OBS_DIM], &[0usize; N_HEADS], 0.0, 0.0, 0.0, false);
    }

    #[test]
    #[should_panic(expected = "in order")]
    fn out_of_order_batched_push_panics() {
        let mut b = RolloutBuffer::new(4, N_HEADS);
        let obs = vec![0f32; 2 * OBS_DIM];
        let actions = vec![[0usize; N_HEADS]; 2];
        let steps = vec![dummy_step(0.0, false, 0.0), dummy_step(0.0, false, 0.0)];
        b.push_step_batch(1, &obs, &actions, &[0.0, 0.0], &[0.0, 0.0], &steps);
    }

    #[test]
    fn buffer_sizes_from_runtime_head_count() {
        // 15-head (learned placement) rows store and gather intact.
        let mut b = RolloutBuffer::new(2, 15);
        assert_eq!(b.actions.len(), 30);
        let mut a = vec![0usize; 15];
        a[14] = 3;
        b.push(&[0.0; OBS_DIM], &a, -1.0, 1.0, 0.5, false);
        b.push(&[0.0; OBS_DIM], &a, -1.0, 1.0, 0.5, true);
        assert_eq!(b.actions[14], 3);
        b.compute_gae(0.0, 0.99, 0.95, 1.0);
        let mut obs = vec![0f32; OBS_DIM];
        let mut actions = vec![0i32; 15];
        let (mut lp, mut adv, mut ret) = (vec![0f32; 1], vec![0f32; 1], vec![0f32; 1]);
        b.gather(&[1], &mut obs, &mut actions, &mut lp, &mut adv, &mut ret);
        assert_eq!(actions[14], 3);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn wrong_arity_push_panics() {
        let mut b = RolloutBuffer::new(2, 15);
        b.push(&[0.0; OBS_DIM], &[0usize; N_HEADS], 0.0, 0.0, 0.0, false);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut b = RolloutBuffer::new(1, N_HEADS);
        let obs = [0f32; OBS_DIM];
        let act = [0usize; N_HEADS];
        b.push(&obs, &act, 0.0, 0.0, 0.0, false);
        b.push(&obs, &act, 0.0, 0.0, 0.0, false);
    }
}

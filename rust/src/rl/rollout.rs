//! Rollout buffer with Generalized Advantage Estimation (SB3 semantics).
//!
//! Stores one on-policy batch of `n_steps` transitions, then computes
//! GAE(γ, λ) advantages and returns. Matches SB3's `RolloutBuffer`:
//! `delta = r + γ·V(s') ·(1−done) − V(s)`,
//! `adv = delta + γλ·(1−done)·adv'`, `ret = adv + V(s)`.

use crate::gym::OBS_DIM;
use crate::model::space::N_HEADS;

/// One on-policy rollout batch.
#[derive(Clone, Debug)]
pub struct RolloutBuffer {
    pub n_steps: usize,
    pub obs: Vec<f32>,        // n_steps × OBS_DIM
    pub actions: Vec<i32>,    // n_steps × N_HEADS
    pub log_probs: Vec<f32>,  // n_steps
    pub rewards: Vec<f64>,    // n_steps (raw env scale)
    pub values: Vec<f32>,     // n_steps
    pub dones: Vec<bool>,     // n_steps (episode ended AFTER this step)
    pub advantages: Vec<f32>, // n_steps
    pub returns: Vec<f32>,    // n_steps
    pos: usize,
}

impl RolloutBuffer {
    pub fn new(n_steps: usize) -> RolloutBuffer {
        RolloutBuffer {
            n_steps,
            obs: vec![0.0; n_steps * OBS_DIM],
            actions: vec![0; n_steps * N_HEADS],
            log_probs: vec![0.0; n_steps],
            rewards: vec![0.0; n_steps],
            values: vec![0.0; n_steps],
            dones: vec![false; n_steps],
            advantages: vec![0.0; n_steps],
            returns: vec![0.0; n_steps],
            pos: 0,
        }
    }

    pub fn clear(&mut self) {
        self.pos = 0;
    }

    pub fn is_full(&self) -> bool {
        self.pos == self.n_steps
    }

    pub fn len(&self) -> usize {
        self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.pos == 0
    }

    /// Append one transition.
    pub fn push(
        &mut self,
        obs: &[f32; OBS_DIM],
        action: &[usize; N_HEADS],
        log_prob: f64,
        reward: f64,
        value: f32,
        done: bool,
    ) {
        assert!(self.pos < self.n_steps, "rollout buffer overflow");
        let o = self.pos * OBS_DIM;
        self.obs[o..o + OBS_DIM].copy_from_slice(obs);
        let a = self.pos * N_HEADS;
        for (i, &x) in action.iter().enumerate() {
            self.actions[a + i] = x as i32;
        }
        self.log_probs[self.pos] = log_prob as f32;
        self.rewards[self.pos] = reward;
        self.values[self.pos] = value;
        self.dones[self.pos] = done;
        self.pos += 1;
    }

    /// Compute GAE advantages and returns. `last_value` bootstraps the
    /// final state; `reward_scale` maps raw env rewards into the network's
    /// value range (SB3 users typically wrap the env — we divide here).
    pub fn compute_gae(&mut self, last_value: f32, gamma: f64, lam: f64, reward_scale: f64) {
        assert!(self.is_full(), "compute_gae on partial rollout");
        let mut adv = 0.0f64;
        for t in (0..self.n_steps).rev() {
            let non_terminal = if self.dones[t] { 0.0 } else { 1.0 };
            let next_value = if t + 1 < self.n_steps {
                if self.dones[t] { 0.0 } else { self.values[t + 1] as f64 }
            } else {
                non_terminal * last_value as f64
            };
            let r = self.rewards[t] / reward_scale;
            let delta = r + gamma * next_value - self.values[t] as f64;
            adv = delta + gamma * lam * non_terminal * adv;
            self.advantages[t] = adv as f32;
            self.returns[t] = (adv + self.values[t] as f64) as f32;
        }
    }

    /// Gather a minibatch by index list into the provided scratch arrays.
    #[allow(clippy::too_many_arguments)]
    pub fn gather(
        &self,
        idx: &[usize],
        obs: &mut [f32],
        actions: &mut [i32],
        log_probs: &mut [f32],
        advantages: &mut [f32],
        returns: &mut [f32],
    ) {
        for (row, &i) in idx.iter().enumerate() {
            obs[row * OBS_DIM..(row + 1) * OBS_DIM]
                .copy_from_slice(&self.obs[i * OBS_DIM..(i + 1) * OBS_DIM]);
            actions[row * N_HEADS..(row + 1) * N_HEADS]
                .copy_from_slice(&self.actions[i * N_HEADS..(i + 1) * N_HEADS]);
            log_probs[row] = self.log_probs[i];
            advantages[row] = self.advantages[i];
            returns[row] = self.returns[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(n: usize, rewards: &[f64], values: &[f32], dones: &[bool]) -> RolloutBuffer {
        let mut b = RolloutBuffer::new(n);
        for t in 0..n {
            b.push(
                &[0.0; OBS_DIM],
                &[0usize; N_HEADS],
                -1.0,
                rewards[t],
                values[t],
                dones[t],
            );
        }
        b
    }

    #[test]
    fn gae_matches_hand_computation_no_done() {
        // 2 steps, no terminal: standard recursive GAE.
        let mut b = filled(2, &[1.0, 1.0], &[0.5, 0.5], &[false, false]);
        let (g, l, last_v) = (0.99, 0.95, 0.5f32);
        b.compute_gae(last_v, g, l, 1.0);
        let d1 = 1.0 + g * 0.5 - 0.5;
        let a1 = d1;
        let d0 = 1.0 + g * 0.5 - 0.5;
        let a0 = d0 + g * l * a1;
        assert!((b.advantages[1] as f64 - a1).abs() < 1e-6);
        assert!((b.advantages[0] as f64 - a0).abs() < 1e-6);
        assert!((b.returns[0] as f64 - (a0 + 0.5)).abs() < 1e-6);
    }

    #[test]
    fn done_cuts_bootstrap() {
        // terminal at t=0: its advantage ignores V(s1).
        let mut b = filled(2, &[2.0, 0.0], &[0.5, 9.0], &[true, false]);
        b.compute_gae(9.0, 0.99, 0.95, 1.0);
        let a0 = 2.0 - 0.5; // no next value, no propagation from t=1
        assert!((b.advantages[0] as f64 - a0).abs() < 1e-6);
    }

    #[test]
    fn terminal_last_step_ignores_last_value() {
        let mut b = filled(1, &[1.0], &[0.0], &[true]);
        b.compute_gae(100.0, 0.99, 0.95, 1.0);
        assert!((b.advantages[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn reward_scale_divides() {
        let mut a = filled(1, &[100.0], &[0.0], &[true]);
        a.compute_gae(0.0, 0.99, 0.95, 100.0);
        assert!((a.advantages[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gather_permutes_rows() {
        let mut b = RolloutBuffer::new(3);
        for t in 0..3 {
            let mut obs = [0f32; OBS_DIM];
            obs[0] = t as f32;
            let mut act = [0usize; N_HEADS];
            act[0] = t;
            b.push(&obs, &act, -(t as f64), t as f64, t as f32, false);
        }
        b.compute_gae(0.0, 0.99, 0.95, 1.0);
        let idx = [2usize, 0];
        let mut obs = vec![0f32; 2 * OBS_DIM];
        let mut actions = vec![0i32; 2 * N_HEADS];
        let mut lp = vec![0f32; 2];
        let mut adv = vec![0f32; 2];
        let mut ret = vec![0f32; 2];
        b.gather(&idx, &mut obs, &mut actions, &mut lp, &mut adv, &mut ret);
        assert_eq!(obs[0], 2.0);
        assert_eq!(obs[OBS_DIM], 0.0);
        assert_eq!(actions[0], 2);
        assert_eq!(lp[0], -2.0);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut b = RolloutBuffer::new(1);
        let obs = [0f32; OBS_DIM];
        let act = [0usize; N_HEADS];
        b.push(&obs, &act, 0.0, 0.0, 0.0, false);
        b.push(&obs, &act, 0.0, 0.0, 0.0, false);
    }
}

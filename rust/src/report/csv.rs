//! Minimal CSV writer for figure/table series.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::Result;

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    ncol: usize,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> Result<CsvWriter> {
        let file = File::create(path)?;
        let mut out = BufWriter::new(file);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, ncol: header.len() })
    }

    /// Write one row of already-formatted cells.
    pub fn row_str(&mut self, cells: &[String]) -> Result<()> {
        assert_eq!(cells.len(), self.ncol, "csv row width mismatch");
        for cell in cells {
            assert!(
                !cell.contains(',') && !cell.contains('\n'),
                "csv cell needs quoting: {cell:?}"
            );
        }
        writeln!(self.out, "{}", cells.join(","))?;
        Ok(())
    }

    /// Write one row of numbers.
    pub fn row(&mut self, cells: &[f64]) -> Result<()> {
        let s: Vec<String> = cells.iter().map(|x| format!("{x}")).collect();
        self.row_str(&s)
    }

    /// Write a labeled row: first column a string, rest numbers.
    pub fn labeled_row(&mut self, label: &str, cells: &[f64]) -> Result<()> {
        let mut s = vec![label.to_string()];
        s.extend(cells.iter().map(|x| format!("{x}")));
        self.row_str(&s)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("chiplet_gym_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&[1.0, 2.5]).unwrap();
            w.labeled_row("x", &[3.0]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2.5\nx,3\n");
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn rejects_ragged() {
        let dir = std::env::temp_dir().join("chiplet_gym_csv_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let mut w = CsvWriter::create(&dir.join("t.csv"), &["a", "b"]).unwrap();
        w.row(&[1.0]).unwrap();
    }
}

//! Minimal CSV writer (RFC-4180 quoting) for figure/table series, plus
//! the shared candidate-table emitter every portfolio surface (the
//! `ga`/`greedy`/`portfolio` subcommands, `benches/perf_search.rs`)
//! writes its results through.

use std::borrow::Cow;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::Result;

use crate::model::space::DesignSpace;
use crate::opt::combined::Candidate;
use crate::opt::search::Certification;

/// RFC-4180-quote one cell: cells containing a comma, double quote, CR
/// or LF are wrapped in double quotes with embedded quotes doubled;
/// everything else passes through unallocated.
pub fn quote(cell: &str) -> Cow<'_, str> {
    if cell.chars().any(|c| matches!(c, ',' | '"' | '\n' | '\r')) {
        Cow::Owned(format!("\"{}\"", cell.replace('"', "\"\"")))
    } else {
        Cow::Borrowed(cell)
    }
}

fn write_record<W: Write, S: AsRef<str>>(out: &mut W, cells: &[S]) -> Result<()> {
    for (i, cell) in cells.iter().enumerate() {
        if i > 0 {
            write!(out, ",")?;
        }
        write!(out, "{}", quote(cell.as_ref()))?;
    }
    writeln!(out)?;
    Ok(())
}

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    ncol: usize,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> Result<CsvWriter> {
        let file = File::create(path)?;
        let mut out = BufWriter::new(file);
        write_record(&mut out, header)?;
        Ok(CsvWriter { out, ncol: header.len() })
    }

    /// Write one row of cells, quoting whatever needs it.
    pub fn row_str(&mut self, cells: &[String]) -> Result<()> {
        assert_eq!(cells.len(), self.ncol, "csv row width mismatch");
        write_record(&mut self.out, cells)
    }

    /// Write one row of numbers.
    pub fn row(&mut self, cells: &[f64]) -> Result<()> {
        let s: Vec<String> = cells.iter().map(|x| format!("{x}")).collect();
        self.row_str(&s)
    }

    /// Write a labeled row: first column a string, rest numbers.
    pub fn labeled_row(&mut self, label: &str, cells: &[f64]) -> Result<()> {
        let mut s = vec![label.to_string()];
        s.extend(cells.iter().map(|x| format!("{x}")));
        self.row_str(&s)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// One row per optimizer candidate (source, seed, reward, key PPAC
/// metrics, decoded chiplet count, raw action) — the common tabular form
/// of `opt::combined::OptOutcome::candidates`.
pub fn write_candidates_csv(
    path: &Path,
    space: &DesignSpace,
    candidates: &[Candidate],
) -> Result<()> {
    let mut out = BufWriter::new(File::create(path)?);
    write_candidates_csv_to(&mut out, space, candidates)?;
    out.flush()?;
    Ok(())
}

/// [`write_candidates_csv`] into any `Write` sink — the serve API uses
/// it to assemble `GET /jobs/<id>/results.csv` in memory, byte-identical
/// to the file the one-shot subcommands would have written.
pub fn write_candidates_csv_to<W: Write>(
    out: &mut W,
    space: &DesignSpace,
    candidates: &[Candidate],
) -> Result<()> {
    write_record(
        out,
        &[
            "source",
            "seed",
            "reward",
            "feasible",
            "throughput_tops",
            "energy_mj_per_task",
            "die_cost",
            "pkg_cost",
            "n_chiplets",
            "action",
        ],
    )?;
    for c in candidates {
        let p = space.decode(&c.action);
        let action = c
            .action
            .iter()
            .map(|x| x.to_string())
            .collect::<Vec<_>>()
            .join(",");
        write_record(
            out,
            &[
                c.source.clone(),
                c.seed.to_string(),
                format!("{}", c.eval.reward),
                c.eval.feasible.to_string(),
                format!("{}", c.eval.throughput_tops),
                format!("{}", c.eval.energy_mj_per_ref_task),
                format!("{}", c.eval.die_cost),
                format!("{}", c.eval.pkg_cost),
                p.n_chiplets.to_string(),
                action,
            ],
        )?;
    }
    Ok(())
}

/// [`write_candidates_csv`] plus the certification columns a
/// branch-and-bound run stamps: certified optimality gap and node
/// counters. They are run-level facts (one certificate per table), so
/// the same three cells repeat on every row; without a certificate the
/// cells are empty — column positions stay pinned either way (golden
/// test below), so downstream consumers never shift.
pub fn write_certified_candidates_csv(
    path: &Path,
    space: &DesignSpace,
    candidates: &[Candidate],
    cert: Option<&Certification>,
) -> Result<()> {
    let mut w = CsvWriter::create(
        path,
        &[
            "source",
            "seed",
            "reward",
            "feasible",
            "throughput_tops",
            "energy_mj_per_task",
            "die_cost",
            "pkg_cost",
            "n_chiplets",
            "action",
            "optimality_gap",
            "nodes_expanded",
            "nodes_pruned",
        ],
    )?;
    let (gap, expanded, pruned) = match cert {
        Some(c) => (
            format!("{}", c.optimality_gap),
            c.nodes_expanded.to_string(),
            c.nodes_pruned.to_string(),
        ),
        None => (String::new(), String::new(), String::new()),
    };
    for c in candidates {
        let p = space.decode(&c.action);
        let action = c
            .action
            .iter()
            .map(|x| x.to_string())
            .collect::<Vec<_>>()
            .join(",");
        w.row_str(&[
            c.source.clone(),
            c.seed.to_string(),
            format!("{}", c.eval.reward),
            c.eval.feasible.to_string(),
            format!("{}", c.eval.throughput_tops),
            format!("{}", c.eval.energy_mj_per_ref_task),
            format!("{}", c.eval.die_cost),
            format!("{}", c.eval.pkg_cost),
            p.n_chiplets.to_string(),
            action,
            gap.clone(),
            expanded.clone(),
            pruned.clone(),
        ])?;
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("chiplet_gym_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&[1.0, 2.5]).unwrap();
            w.labeled_row("x", &[3.0]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2.5\nx,3\n");
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn rejects_ragged() {
        let dir = std::env::temp_dir().join("chiplet_gym_csv_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let mut w = CsvWriter::create(&dir.join("t.csv"), &["a", "b"]).unwrap();
        w.row(&[1.0]).unwrap();
    }

    #[test]
    fn quote_is_rfc4180() {
        assert_eq!(quote("plain"), "plain");
        assert_eq!(quote(""), "");
        assert_eq!(quote("a,b"), "\"a,b\"");
        assert_eq!(quote("he said \"hi\""), "\"he said \"\"hi\"\"\"");
        assert_eq!(quote("two\nlines"), "\"two\nlines\"");
        assert_eq!(quote("cr\rcell"), "\"cr\rcell\"");
    }

    #[test]
    fn candidates_csv_has_one_row_per_candidate_and_quotes_actions() {
        use crate::cost::{evaluate, Calib};
        use crate::model::space::N_HEADS;
        let dir = std::env::temp_dir().join("chiplet_gym_csv_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cands.csv");
        let space = DesignSpace::case_i();
        let calib = Calib::default();
        let action = vec![0usize; N_HEADS];
        let eval = evaluate(&calib, &space.decode(&action));
        let cands = vec![
            Candidate { source: "SA".into(), seed: 0, action: action.clone(), eval },
            Candidate { source: "GA".into(), seed: 1, action, eval },
        ];
        write_candidates_csv(&path, &space, &cands).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("source,seed,reward"));
        assert!(text.contains("GA,1,"));
        // the 14-head action list lands in one RFC-4180-quoted cell
        assert!(text.contains("\"0,0,0"));
    }

    #[test]
    fn in_memory_candidates_csv_is_byte_identical_to_the_file() {
        use crate::cost::{evaluate, Calib};
        use crate::model::space::N_HEADS;
        let dir = std::env::temp_dir().join("chiplet_gym_csv_test6");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cands.csv");
        let space = DesignSpace::case_i();
        let calib = Calib::default();
        let action = vec![0usize; N_HEADS];
        let eval = evaluate(&calib, &space.decode(&action));
        let cands = vec![Candidate { source: "SA".into(), seed: 3, action, eval }];
        write_candidates_csv(&path, &space, &cands).unwrap();
        let mut buf: Vec<u8> = Vec::new();
        write_candidates_csv_to(&mut buf, &space, &cands).unwrap();
        assert_eq!(buf, std::fs::read(&path).unwrap());
    }

    #[test]
    fn certified_candidates_csv_golden_header_and_cells() {
        use crate::cost::{evaluate, Calib};
        use crate::model::space::N_HEADS;
        let dir = std::env::temp_dir().join("chiplet_gym_csv_test5");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("certified.csv");
        let space = DesignSpace::case_i();
        let calib = Calib::default();
        let action = vec![0usize; N_HEADS];
        let eval = evaluate(&calib, &space.decode(&action));
        let cand = Candidate { source: "bnb".into(), seed: 0, action: action.clone(), eval };
        let cands = vec![cand];
        let cert = Certification {
            optimality_gap: 1.5,
            root_bound: 10.0,
            nodes_expanded: 42,
            nodes_pruned: 7,
            leaf_evals: 5,
            complete: false,
        };

        // Golden header — pinned so sweep consumers don't silently break.
        write_certified_candidates_csv(&path, &space, &cands, Some(&cert)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        assert_eq!(
            lines.next().unwrap(),
            "source,seed,reward,feasible,throughput_tops,energy_mj_per_task,\
             die_cost,pkg_cost,n_chiplets,action,optimality_gap,nodes_expanded,nodes_pruned"
        );
        let row = lines.next().unwrap();
        assert!(row.ends_with(",1.5,42,7"), "{row}");
        // RFC-4180 round-trip: the action cell is the only quoted one,
        // and un-quoting it recovers the raw head list.
        let raw = action.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",");
        let quoted = format!("\"{raw}\"");
        assert!(row.contains(&quoted), "{row}");

        // Without a certificate the columns stay, cells go empty.
        write_certified_candidates_csv(&path, &space, &cands, None).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let row = text.lines().nth(1).unwrap();
        assert!(row.ends_with(",,,"), "{row}");
    }

    #[test]
    fn special_cells_roundtrip_quoted_instead_of_panicking() {
        // Regression: row_str used to assert!() on commas/newlines.
        let dir = std::env::temp_dir().join("chiplet_gym_csv_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["name", "action,list"]).unwrap();
            w.row_str(&["0,59,29".to_string(), "say \"go\"\nnow".to_string()]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            text,
            "name,\"action,list\"\n\"0,59,29\",\"say \"\"go\"\"\nnow\"\n"
        );
    }
}

//! Result emitters: CSV series for every paper figure and aligned tables
//! for the paper's tables, written under `bench_results/`.

pub mod csv;

pub use csv::{write_candidates_csv, write_candidates_csv_to, CsvWriter};

use std::path::{Path, PathBuf};

/// Resolve (and create) the bench-results directory.
pub fn results_dir() -> PathBuf {
    let dir = std::env::var("CHIPLET_GYM_RESULTS").unwrap_or_else(|_| "bench_results".into());
    let path = PathBuf::from(dir);
    std::fs::create_dir_all(&path).expect("creating bench_results dir");
    path
}

/// Write a small text report next to the CSVs.
pub fn write_text(name: &str, contents: &str) -> PathBuf {
    let path = results_dir().join(name);
    std::fs::write(&path, contents).expect("writing report text");
    path
}

/// Helper for benches: emit a named CSV under the results dir.
pub fn csv(name: &str, header: &[&str]) -> CsvWriter {
    CsvWriter::create(&results_dir().join(name), header).expect("creating csv")
}

/// Path helper for tests.
pub fn result_path(name: &str) -> PathBuf {
    results_dir().join(name)
}

/// Format a paper-vs-measured comparison line for EXPERIMENTS.md-style
/// logs.
pub fn compare_line(metric: &str, paper: f64, measured: f64) -> String {
    let ratio = if paper != 0.0 { measured / paper } else { f64::NAN };
    format!("{metric}: paper={paper:.3} measured={measured:.3} (x{ratio:.2})")
}

#[allow(unused)]
fn _path_is_send(p: &Path) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compare_line_formats() {
        let s = compare_line("throughput", 2.0, 3.0);
        assert!(s.contains("x1.50"), "{s}");
    }
}

//! Fig. 8: (a) impact of the entropy coefficient on PPO convergence,
//! (b) impact of the initial temperature on SA convergence.
//!
//! Quick mode trains 24K steps per entropy setting and runs 100K SA
//! iterations per temperature; CHIPLET_GYM_FULL=1 restores the paper's
//! 250K / 500K. Emits `bench_results/fig8a_entropy.csv` and
//! `bench_results/fig8b_sa_temp.csv`.

use chiplet_gym::cost::Calib;
use chiplet_gym::gym::ChipletGymEnv;
use chiplet_gym::model::space::DesignSpace;
use chiplet_gym::opt::sa::{simulated_annealing, SaConfig};
use chiplet_gym::report;
use chiplet_gym::rl::{train_ppo, PpoConfig};
use chiplet_gym::runtime::Engine;

fn main() {
    let full = std::env::var("CHIPLET_GYM_FULL").is_ok();

    // ---- (b) SA temperature — no artifacts needed ----
    let space = DesignSpace::case_i();
    let calib = Calib::default();
    let sa_iters = if full { 500_000 } else { 100_000 };
    let mut csv_b = report::csv(
        "fig8b_sa_temp.csv",
        &["temperature", "iteration", "best_objective"],
    );
    for &temp in &[1.0f64, 200.0] {
        let cfg = SaConfig {
            iterations: sa_iters,
            temperature: temp,
            step_size: 10.0,
            trace_every: sa_iters / 100,
        };
        let trace = simulated_annealing(&space, &calib, &cfg, 0);
        for &(iter, obj) in &trace.history {
            csv_b.row(&[temp, iter as f64, obj]).unwrap();
        }
        println!(
            "SA temp {temp:>5}: best {:.2} after {sa_iters} iters",
            trace.best_eval.reward
        );
    }
    csv_b.flush().unwrap();
    println!("(paper Fig. 8b: higher temperature reaches a higher cost-model value)\n");

    // ---- (a) PPO entropy coefficient ----
    let engine = match Engine::discover() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP fig8a (artifacts missing): {e:#}");
            return;
        }
    };
    let timesteps = if full { 250_000 } else { 24_576 };
    let mut csv_a = report::csv(
        "fig8a_entropy.csv",
        &["ent_coef", "timesteps", "ep_rew_mean", "cost_value", "entropy"],
    );
    for &ent in &[0.0f64, 0.1] {
        let mut cfg = PpoConfig::from_manifest(&engine);
        cfg.total_timesteps = timesteps;
        cfg.ent_coef = ent;
        let mut env = ChipletGymEnv::case_i();
        let trace = train_ppo(&engine, &mut env, &cfg, 0).expect("ppo");
        for s in &trace.history {
            csv_a
                .row(&[ent, s.timesteps as f64, s.ep_rew_mean, s.cost_value, s.entropy])
                .unwrap();
        }
        let last = trace.history.last().unwrap();
        println!(
            "PPO ent_coef {ent}: ep_rew_mean {:.1}, policy entropy {:.2}, best {:.1}",
            last.ep_rew_mean, last.entropy, trace.best_reward
        );
    }
    csv_a.flush().unwrap();
    println!("(paper Fig. 8a: ent 0.1 converges higher, ent 0 stabilizes lower, faster)");
    println!(
        "wrote {} and {}",
        report::result_path("fig8a_entropy.csv").display(),
        report::result_path("fig8b_sa_temp.csv").display()
    );
}

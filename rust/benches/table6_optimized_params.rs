//! Table 6: optimized parameters found by the combined optimizer for
//! α, β, γ = [1, 1, 0.1], cases (i) and (ii) — side by side with the
//! paper's reported optimum.
//!
//! Also prints Tables 3–4 (the interconnect property inputs). Quick mode:
//! 6 SA seeds × 150K iters + 2 RL seeds × 32K steps; CHIPLET_GYM_FULL=1
//! restores the paper's 20+20 × (500K / 250K).
//! Emits `bench_results/table6_optimized.csv`.

use chiplet_gym::cost::{evaluate, Calib};
use chiplet_gym::model::packaging::INTERCONNECTS;
use chiplet_gym::model::space::{paper_points, DesignSpace};
use chiplet_gym::opt::combined::{combined_optimize, sa_only_optimize, CombinedConfig};
use chiplet_gym::opt::sa::SaConfig;
use chiplet_gym::report;
use chiplet_gym::rl::PpoConfig;
use chiplet_gym::runtime::Engine;
use chiplet_gym::util::table::Table;

fn main() {
    // ---- Tables 3-4 preamble ----
    let mut t34 = Table::new(["interconnect", "class", "pitch (um)", "pJ/bit", "cost tier"]);
    for ic in INTERCONNECTS {
        let p = ic.props();
        t34.row([
            p.name.to_string(),
            format!("{:?}", p.class),
            format!("{}", p.bump_pitch_um),
            format!("{}-{}", p.e_bit_min_pj, p.e_bit_max_pj),
            format!("{:?}", p.cost_tier),
        ]);
    }
    println!("Table 4 inputs:");
    t34.print();

    let full = std::env::var("CHIPLET_GYM_FULL").is_ok();
    let calib = Calib::default();
    let engine = Engine::discover().ok();

    let mut csv = report::csv(
        "table6_optimized.csv",
        &["case", "source", "objective", "arch", "n_chiplets", "n_hbm",
          "ai2ai_tbps", "ai2ai_3d_tbps", "ai2hbm_tbps"],
    );

    for (case, space, paper_action) in [
        ("i", DesignSpace::case_i(), paper_points::table6_case_i()),
        ("ii", DesignSpace::case_ii(), paper_points::table6_case_ii()),
    ] {
        println!("\n=== Table 6 case ({case}), alpha,beta,gamma = [1,1,0.1] ===");
        let sa = SaConfig {
            iterations: if full { 500_000 } else { 150_000 },
            trace_every: 0,
            ..SaConfig::default()
        };
        let outcome = if let Some(engine) = &engine {
            let mut ppo = PpoConfig::from_manifest(engine);
            ppo.total_timesteps = if full { 250_000 } else { 32_768 };
            let cfg = CombinedConfig {
                sa,
                ppo,
                sa_seeds: if full { (0..20).collect() } else { (0..6).collect() },
                rl_seeds: if full { (0..20).collect() } else { (0..2).collect() },
                extra: Vec::new(),
            };
            combined_optimize(Some(engine), space, &calib, &cfg).expect("alg1")
        } else {
            sa_only_optimize(space, &calib, &sa, &(0..6).collect::<Vec<_>>())
        };

        let ours = space.decode(&outcome.best.action);
        let ours_eval = evaluate(&calib, &ours);
        let paper = space.decode(&paper_action);
        let paper_eval = evaluate(&calib, &paper);

        let mut t = Table::new(["parameter", "ours (Alg. 1)", "paper Table 6"]);
        t.row(["objective".to_string(),
               format!("{:.1}", ours_eval.reward),
               format!("{:.1}", paper_eval.reward)]);
        t.row(["architecture".to_string(), ours.arch.name().into(), paper.arch.name().into()]);
        t.row(["chiplets".to_string(),
               format!("{} ({}x{})", ours.n_chiplets, ours_eval.mesh_m, ours_eval.mesh_n),
               format!("{} ({}x{})", paper.n_chiplets, paper_eval.mesh_m, paper_eval.mesh_n)]);
        t.row(["HBMs".to_string(),
               format!("{} @ {:?}", ours.n_hbm(), ours.hbm_locs()),
               format!("{} @ {:?}", paper.n_hbm(), paper.hbm_locs())]);
        t.row(["AI2AI 2.5D".to_string(),
               format!("{} {}Gbps x{}", ours.ai2ai_25d.props().name, ours.ai2ai_25d_gbps, ours.ai2ai_25d_links),
               format!("{} {}Gbps x{}", paper.ai2ai_25d.props().name, paper.ai2ai_25d_gbps, paper.ai2ai_25d_links)]);
        t.row(["AI2AI 3D".to_string(),
               format!("{} {}Gbps x{}", ours.ai2ai_3d.props().name, ours.ai2ai_3d_gbps, ours.ai2ai_3d_links),
               format!("{} {}Gbps x{}", paper.ai2ai_3d.props().name, paper.ai2ai_3d_gbps, paper.ai2ai_3d_links)]);
        t.row(["AI2HBM".to_string(),
               format!("{} {}Gbps x{} ({:.0} Tbps)", ours.ai2hbm.props().name, ours.ai2hbm_gbps, ours.ai2hbm_links, ours.bw_ai2hbm_tbps()),
               format!("{} {}Gbps x{} ({:.0} Tbps)", paper.ai2hbm.props().name, paper.ai2hbm_gbps, paper.ai2hbm_links, paper.bw_ai2hbm_tbps())]);
        t.print();

        csv.row_str(&[
            case.to_string(), outcome.best.source.clone(),
            format!("{:.2}", ours_eval.reward), ours.arch.name().to_string(),
            format!("{}", ours.n_chiplets), format!("{}", ours.n_hbm()),
            format!("{:.1}", ours.bw_ai2ai_25d_tbps()),
            format!("{:.1}", ours.bw_ai2ai_3d_tbps()),
            format!("{:.1}", ours.bw_ai2hbm_tbps()),
        ]).unwrap();
    }
    csv.flush().unwrap();
    println!("\nwrote {}", report::result_path("table6_optimized.csv").display());
}

//! Fig. 11: highest cost-model value achieved by SA and RL per run,
//! for case (i) and case (ii).
//!
//! The paper reports RL at 178–185 (case i) / 188–194 (case ii) and SA at
//! 151–176 / 170–188 over 10 runs. Quick mode uses 10 SA × 100K iters and
//! 4 RL × 32K steps; CHIPLET_GYM_FULL=1 restores 500K / 10 × 250K.
//! Emits `bench_results/fig11_best_values.csv`.

use chiplet_gym::cost::Calib;
use chiplet_gym::gym::ChipletGymEnv;
use chiplet_gym::model::space::DesignSpace;
use chiplet_gym::opt::sa::{simulated_annealing, SaConfig};
use chiplet_gym::report;
use chiplet_gym::rl::{train_ppo, PpoConfig};
use chiplet_gym::runtime::Engine;
use chiplet_gym::util::table::Table;

fn main() {
    let full = std::env::var("CHIPLET_GYM_FULL").is_ok();
    let sa_iters = if full { 500_000 } else { 100_000 };
    let rl_steps = if full { 250_000 } else { 32_768 };
    let sa_seeds: Vec<u64> = (0..10).collect();
    let rl_seeds: Vec<u64> = if full { (0..10).collect() } else { (0..4).collect() };

    let calib = Calib::default();
    let engine = Engine::discover().ok();
    let mut csv = report::csv(
        "fig11_best_values.csv",
        &["case", "optimizer", "seed", "best_objective"],
    );

    for (case, space, paper_rl, paper_sa) in [
        ("i", DesignSpace::case_i(), "178-185", "151-176"),
        ("ii", DesignSpace::case_ii(), "188-194", "170-188"),
    ] {
        let mut t = Table::new(["run", "SA best", "RL best"]);
        let mut sa_all = Vec::new();
        let mut rl_all = Vec::new();
        for (k, &seed) in sa_seeds.iter().enumerate() {
            let cfg = SaConfig {
                iterations: sa_iters,
                trace_every: 0,
                ..SaConfig::default()
            };
            let sa_best = simulated_annealing(&space, &calib, &cfg, seed)
                .best_eval
                .reward;
            csv.labeled_row(case, &[0.0, seed as f64, sa_best]).ok();
            sa_all.push(sa_best);

            let rl_best = if let (Some(engine), true) = (&engine, k < rl_seeds.len()) {
                let mut cfg = PpoConfig::from_manifest(engine);
                cfg.total_timesteps = rl_steps;
                let mut env = ChipletGymEnv::new(space, calib.clone(), cfg.episode_len);
                let b = train_ppo(engine, &mut env, &cfg, seed)
                    .expect("ppo")
                    .best_reward;
                csv.labeled_row(case, &[1.0, seed as f64, b]).ok();
                rl_all.push(b);
                format!("{b:.1}")
            } else {
                "-".to_string()
            };
            t.row([format!("{}", k + 1), format!("{sa_best:.1}"), rl_best]);
        }
        println!("=== Fig. 11 case ({case}) ===");
        t.print();
        let range = |xs: &[f64]| {
            if xs.is_empty() {
                "-".to_string()
            } else {
                format!(
                    "{:.1}-{:.1}",
                    xs.iter().cloned().fold(f64::INFINITY, f64::min),
                    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
                )
            }
        };
        println!(
            "measured: SA {} (paper {paper_sa}), RL {} (paper {paper_rl})\n",
            range(&sa_all),
            range(&rl_all)
        );
    }
    csv.flush().unwrap();
    println!("wrote {}", report::result_path("fig11_best_values.csv").display());
}

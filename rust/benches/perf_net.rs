//! Kernel-layer throughput: `rl::net` forward / `ppo_update` ns per call
//! against the frozen scalar oracle.
//!
//! Times [`NativeNet`] (blocked matmul + fused Adam, reusable scratch)
//! and [`ScalarNet`] (the verbatim pre-kernel per-element loops from
//! `kernels::oracle`) on the same inputs across the {14-head canonical,
//! 15-head learned-placement} × {batch 1, 16, 64} grid, asserting
//! bitwise-identical outputs before timing — a speedup that changed a
//! single bit would be a bug, not a win. A threads axis then times the
//! data-parallel update path (`NativeNet::with_jobs`, sharded over the
//! worker pool) on the 15-head/b64 cell at jobs 1 and 4, again pinned
//! bitwise against the serial kernel first. Writes `BENCH_net.json`
//! (plus a CSV of the rows) under `bench_results/` and fails if
//! throughput fell more than `REGRESSION_TOLERANCE` below the committed
//! baseline.

use chiplet_gym::kernels::oracle::ScalarNet;
use chiplet_gym::model::space::DesignSpace;
use chiplet_gym::report;
use chiplet_gym::rl::init::init_param_entries;
use chiplet_gym::rl::net::{NativeNet, NetShape};
use chiplet_gym::util::bench::{
    enforce_throughput_baseline, fmt_ns, Runner, REGRESSION_TOLERANCE,
};
use chiplet_gym::util::Rng;

/// One benchmark cell: a net shape at a fixed minibatch size, with
/// self-consistent PPO update inputs (old_logp comes from the net's own
/// forward, so ratios start near 1 like a real first epoch).
struct Cell {
    obs: Vec<f32>,
    actions: Vec<i32>,
    old_logp: Vec<f32>,
    advantages: Vec<f32>,
    returns: Vec<f32>,
}

fn build_cell(net: &NativeNet, params: &[f32], m: usize, rng: &mut Rng) -> Cell {
    let shape = &net.shape;
    let (o, nh) = (shape.obs_dim, shape.n_heads());
    let slices = shape.head_slices();
    let obs: Vec<f32> = (0..m * o).map(|_| rng.f32()).collect();
    let mut actions = Vec::with_capacity(m * nh);
    for _ in 0..m {
        for &d in &shape.dims {
            actions.push(rng.below(d as u64) as i32);
        }
    }
    let fwd = net.forward(params, &obs).expect("forward");
    let a = shape.act_total();
    let mut old_logp = Vec::with_capacity(m);
    for b in 0..m {
        let row = &fwd.logp_all[b * a..(b + 1) * a];
        let mut lp = 0.0f64;
        for (h, &(s, _e)) in slices.iter().enumerate() {
            lp += row[s + actions[b * nh + h] as usize] as f64;
        }
        old_logp.push(lp as f32);
    }
    let advantages: Vec<f32> = (0..m).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let returns: Vec<f32> = (0..m).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    Cell { obs, actions, old_logp, advantages, returns }
}

fn assert_identical(net: &NativeNet, oracle: &ScalarNet, params: &[f32], cell: &Cell, m: usize) {
    let hyper = [3e-4f32, 0.2, 0.01];
    let f_new = net.forward(params, &cell.obs).expect("kernel forward");
    let f_old = oracle.forward(params, &cell.obs).expect("oracle forward");
    assert_eq!(f_new.logp_all.len(), f_old.logp_all.len());
    for (a, b) in f_new.logp_all.iter().zip(f_old.logp_all.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "forward logp diverged (batch {m})");
    }
    for (a, b) in f_new.value.iter().zip(f_old.value.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "forward value diverged (batch {m})");
    }
    let pc = params.len();
    let (zm, zv) = (vec![0f32; pc], vec![0f32; pc]);
    let u_new = net
        .ppo_update(
            params, &zm, &zv, 1.0, &cell.obs, &cell.actions, &cell.old_logp, &cell.advantages,
            &cell.returns, hyper,
        )
        .expect("kernel update");
    let u_old = oracle
        .ppo_update(
            params, &zm, &zv, 1.0, &cell.obs, &cell.actions, &cell.old_logp, &cell.advantages,
            &cell.returns, hyper,
        )
        .expect("oracle update");
    for (a, b) in u_new.params.iter().zip(u_old.params.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "updated params diverged (batch {m})");
    }
    for (a, b) in u_new.adam_m.iter().zip(u_old.adam_m.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "adam_m diverged (batch {m})");
    }
    for (a, b) in u_new.adam_v.iter().zip(u_old.adam_v.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "adam_v diverged (batch {m})");
    }
}

fn main() {
    // Committed baseline, read before this run overwrites it.
    let baseline = std::fs::read_to_string(report::result_path("BENCH_net.json")).ok();
    let hyper = [3e-4f32, 0.2, 0.01];
    let cases = [
        ("14-head", DesignSpace::case_i().layout()),
        ("15-head", DesignSpace::case_i().with_placement_head().layout()),
    ];
    let batches = [1usize, 16, 64];

    // (label, batch, forward ns kernel/oracle, update ns kernel/oracle)
    let mut rows: Vec<(String, usize, f64, f64, f64, f64)> = Vec::new();
    for (name, layout) in &cases {
        let shape = NetShape::for_layout(layout);
        let net = NativeNet::new(shape.clone());
        let oracle = ScalarNet::new(shape.clone());
        let params = init_param_entries(&shape.param_entries(), shape.param_count(), 0);
        let pc = params.len();
        let mut rng = Rng::new(42);
        for &m in &batches {
            let cell = build_cell(&net, &params, m, &mut rng);
            assert_identical(&net, &oracle, &params, &cell, m);

            let (zm, zv) = (vec![0f32; pc], vec![0f32; pc]);
            let mut runner = Runner::new();
            runner.bench(&format!("{name}/b{m}: forward (kernel)"), || {
                std::hint::black_box(net.forward(&params, &cell.obs).unwrap());
            });
            let fwd_ns = runner.results().last().unwrap().ns_per_iter.mean;
            runner.bench(&format!("{name}/b{m}: forward (oracle)"), || {
                std::hint::black_box(oracle.forward(&params, &cell.obs).unwrap());
            });
            let fwd_oracle_ns = runner.results().last().unwrap().ns_per_iter.mean;
            runner.bench(&format!("{name}/b{m}: ppo_update (kernel)"), || {
                std::hint::black_box(
                    net.ppo_update(
                        &params, &zm, &zv, 1.0, &cell.obs, &cell.actions, &cell.old_logp,
                        &cell.advantages, &cell.returns, hyper,
                    )
                    .unwrap(),
                );
            });
            let upd_ns = runner.results().last().unwrap().ns_per_iter.mean;
            runner.bench(&format!("{name}/b{m}: ppo_update (oracle)"), || {
                std::hint::black_box(
                    oracle
                        .ppo_update(
                            &params, &zm, &zv, 1.0, &cell.obs, &cell.actions, &cell.old_logp,
                            &cell.advantages, &cell.returns, hyper,
                        )
                        .unwrap(),
                );
            });
            let upd_oracle_ns = runner.results().last().unwrap().ns_per_iter.mean;

            println!(
                "{name:>8}/b{m:<2}: forward {} vs {} ({:.2}x), update {} vs {} ({:.2}x)",
                fmt_ns(fwd_ns),
                fmt_ns(fwd_oracle_ns),
                fwd_oracle_ns / fwd_ns,
                fmt_ns(upd_ns),
                fmt_ns(upd_oracle_ns),
                upd_oracle_ns / upd_ns
            );
            rows.push((format!("{name}/b{m}"), m, fwd_ns, fwd_oracle_ns, upd_ns, upd_oracle_ns));
        }
    }

    // ---- threads axis: the pool-sharded parallel update on the
    // 15-head/b64 perf-target cell. Outputs are asserted bitwise
    // identical to the serial kernel before any timing (jobs-invariance
    // is the whole contract — see tests/parallel_determinism.rs).
    let mut jobs_rows: Vec<(String, usize, f64)> = Vec::new();
    {
        let shape = NetShape::for_layout(&cases[1].1);
        let serial = NativeNet::new(shape.clone());
        let params = init_param_entries(&shape.param_entries(), shape.param_count(), 0);
        let pc = params.len();
        let mut rng = Rng::new(42);
        let m = 64usize;
        let cell = build_cell(&serial, &params, m, &mut rng);
        let (zm, zv) = (vec![0f32; pc], vec![0f32; pc]);
        let want = serial
            .ppo_update(
                &params, &zm, &zv, 1.0, &cell.obs, &cell.actions, &cell.old_logp,
                &cell.advantages, &cell.returns, hyper,
            )
            .expect("serial update");
        for jobs in [1usize, 4] {
            let net = NativeNet::new(shape.clone()).with_jobs(jobs);
            let got = net
                .ppo_update(
                    &params, &zm, &zv, 1.0, &cell.obs, &cell.actions, &cell.old_logp,
                    &cell.advantages, &cell.returns, hyper,
                )
                .expect("parallel update");
            for (a, b) in got.params.iter().zip(want.params.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "jobs {jobs} params diverged");
            }
            let mut runner = Runner::new();
            runner.bench(&format!("15-head/b64/j{jobs}: ppo_update"), || {
                std::hint::black_box(
                    net.ppo_update(
                        &params, &zm, &zv, 1.0, &cell.obs, &cell.actions, &cell.old_logp,
                        &cell.advantages, &cell.returns, hyper,
                    )
                    .unwrap(),
                );
            });
            let ns = runner.results().last().unwrap().ns_per_iter.mean;
            println!(
                "15-head/b64 jobs {jobs} (effective {}): update {}",
                net.jobs(),
                fmt_ns(ns)
            );
            jobs_rows.push((format!("15-head/b64/j{jobs}"), jobs, ns));
        }
        if let [(_, _, n1), (_, _, n4)] = jobs_rows.as_slice() {
            println!("15-head/b64 update jobs-4 speedup: {:.2}x", n1 / n4);
        }
    }

    let mut csv = report::csv(
        "perf_net.csv",
        &[
            "case",
            "batch",
            "forward_ns",
            "forward_oracle_ns",
            "update_ns",
            "update_oracle_ns",
        ],
    );
    for (label, m, f, fo, u, uo) in &rows {
        csv.labeled_row(label, &[*m as f64, *f, *fo, *u, *uo]).expect("csv row");
    }
    csv.flush().expect("csv flush");

    // BENCH_net.json: machine-readable kernel-vs-oracle trajectory,
    // plus the threads-axis block.
    let mut json = String::from("{\n  \"cases\": {\n");
    for (i, (label, m, f, fo, u, uo)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    \"{label}\": {{\"batch\": {m}, \"forward_ns\": {f:.1}, \
             \"forward_oracle_ns\": {fo:.1}, \"update_ns\": {u:.1}, \
             \"update_oracle_ns\": {uo:.1}, \"forward_speedup\": {:.3}, \
             \"update_speedup\": {:.3}, \"update_steps_per_sec\": {:.1}}}{}\n",
            fo / f,
            uo / u,
            1e9 / u,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  },\n  \"jobs\": {\n");
    for (i, (label, jobs, ns)) in jobs_rows.iter().enumerate() {
        json.push_str(&format!(
            "    \"{label}\": {{\"jobs\": {jobs}, \"update_ns\": {ns:.1}, \
             \"update_steps_per_sec\": {:.1}}}{}\n",
            1e9 / ns,
            if i + 1 < jobs_rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  }\n}\n");
    let path = report::write_text("BENCH_net.json", &json);
    println!("wrote {}", path.display());

    let mut fresh: Vec<(String, f64)> = rows
        .iter()
        .map(|(label, _, _, _, u, _)| (format!("cases.{label}.update_steps_per_sec"), 1e9 / u))
        .collect();
    fresh.extend(
        jobs_rows
            .iter()
            .map(|(label, _, ns)| (format!("jobs.{label}.update_steps_per_sec"), 1e9 / ns)),
    );
    enforce_throughput_baseline("perf_net", baseline.as_deref(), &fresh, REGRESSION_TOLERANCE);
}

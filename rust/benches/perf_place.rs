//! Placement-engine throughput: layout evaluations/sec and end-to-end
//! placement-search wall time.
//!
//! Times (a) one `Placement::hop_stats` evaluation of the Table 6
//! case (i) layout — the placement search's inner loop, an O(tiles²)
//! scan instead of the full PPAC model — and (b) one complete
//! `optimize_placement` run at the default greedy budget, for both
//! paper cases. Writes `BENCH_place.json` (plus a CSV of the rows)
//! under `bench_results/` to seed the placement perf trajectory across
//! PRs.

use chiplet_gym::cost::Calib;
use chiplet_gym::model::space::{paper_points, DesignSpace};
use chiplet_gym::opt::search::DriverConfig;
use chiplet_gym::place::{optimize_placement, PlaceConfig, Placement};
use chiplet_gym::report;
use chiplet_gym::util::bench::{fmt_ns, Runner};

fn main() {
    let calib = Calib::default();
    let budget = 2_000usize;
    let cases = [
        ("case-i", DesignSpace::case_i(), paper_points::table6_case_i()),
        ("case-ii", DesignSpace::case_ii(), paper_points::table6_case_ii()),
    ];

    // (label, tiles, hop_stats evals/sec, search wall secs, canonical ns,
    //  optimized ns)
    let mut rows: Vec<(String, usize, f64, f64, f64, f64)> = Vec::new();
    for (name, space, action) in &cases {
        let p = space.decode(action);
        let layout = Placement::canonical(p.n_footprints(), &p.hbm_locs());

        let mut runner = Runner::new();
        runner.bench(&format!("{name}: hop_stats ({} tiles)", p.n_footprints()), || {
            std::hint::black_box(layout.hop_stats());
        });
        let stats_ns = runner.results().last().unwrap().ns_per_iter.mean;
        let evals_per_sec = 1e9 / stats_ns;

        let cfg = PlaceConfig { driver: DriverConfig::greedy_with_budget(budget), seed: 0 };
        let mut canonical_ns = 0.0;
        let mut optimized_ns = 0.0;
        let mut quick = Runner::quick();
        quick.bench(&format!("{name}: optimize_placement ({budget}-eval greedy)"), || {
            let out = optimize_placement(space, &calib, &p, &cfg);
            canonical_ns = out.canonical_ns;
            optimized_ns = out.optimized_ns;
            std::hint::black_box(out.placement.hbm.len());
        });
        let search_secs = quick.results().last().unwrap().ns_per_iter.mean / 1e9;

        println!(
            "{name:>8}: hop_stats {} ({evals_per_sec:.0} evals/s), \
             search {search_secs:.3}s, comm {canonical_ns:.2} -> {optimized_ns:.2} ns",
            fmt_ns(stats_ns)
        );
        rows.push((
            name.to_string(),
            p.n_footprints(),
            evals_per_sec,
            search_secs,
            canonical_ns,
            optimized_ns,
        ));
    }

    let mut csv = report::csv(
        "perf_place.csv",
        &[
            "case",
            "tiles",
            "hop_stats_evals_per_sec",
            "search_secs",
            "canonical_comm_ns",
            "optimized_comm_ns",
        ],
    );
    for (name, tiles, eps, secs, can, opt) in &rows {
        csv.labeled_row(name, &[*tiles as f64, *eps, *secs, *can, *opt]).expect("csv row");
    }
    csv.flush().expect("csv flush");

    // BENCH_place.json: the machine-readable perf-trajectory seed.
    let mut json = String::from("{\n  \"budget\": ");
    json.push_str(&budget.to_string());
    json.push_str(",\n  \"cases\": {\n");
    for (i, (name, tiles, eps, secs, can, opt)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    \"{name}\": {{\"tiles\": {tiles}, \"hop_stats_evals_per_sec\": {eps:.1}, \
             \"search_secs\": {secs:.4}, \"canonical_comm_ns\": {can:.4}, \
             \"optimized_comm_ns\": {opt:.4}}}{}\n",
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  }\n}\n");
    let path = report::write_text("BENCH_place.json", &json);
    println!("wrote {}", path.display());
}

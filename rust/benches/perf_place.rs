//! Placement-engine throughput: layout evaluations/sec and end-to-end
//! placement-search wall time.
//!
//! Times (a) one `Placement::hop_stats` evaluation of the Table 6
//! case (i) layout — the placement search's inner loop, an O(tiles²)
//! scan instead of the full PPAC model — and (b) one complete
//! `optimize_placement` run at the default greedy budget, for both
//! paper cases. Writes `BENCH_place.json` (plus a CSV of the rows)
//! under `bench_results/` to seed the placement perf trajectory across
//! PRs.

use chiplet_gym::cost::Calib;
use chiplet_gym::kernels::HopField;
use chiplet_gym::model::space::{paper_points, DesignSpace};
use chiplet_gym::opt::search::DriverConfig;
use chiplet_gym::place::{optimize_placement, HbmAttach, PlaceConfig, Placement};
use chiplet_gym::report;
use chiplet_gym::util::bench::{
    enforce_throughput_baseline, fmt_ns, Runner, REGRESSION_TOLERANCE,
};
use chiplet_gym::util::Rng;

/// Full-grid placement with `k` random HBM attaches — the shape the
/// attach-point optimizer scores thousands of times per search.
fn grid_placement(m: usize, n: usize, k: usize, rng: &mut Rng) -> Placement {
    let mut tiles = Vec::with_capacity(m * n);
    for r in 0..m {
        for c in 0..n {
            tiles.push((r, c));
        }
    }
    let hbm = (0..k)
        .map(|_| HbmAttach {
            tile: (rng.below(m as u64) as usize, rng.below(n as u64) as usize),
            extra_hops: 1,
        })
        .collect();
    Placement { m, n, tiles, hbm }
}

fn main() {
    let baseline = std::fs::read_to_string(report::result_path("BENCH_place.json")).ok();
    let calib = Calib::default();
    let budget = 2_000usize;
    let cases = [
        ("case-i", DesignSpace::case_i(), paper_points::table6_case_i()),
        ("case-ii", DesignSpace::case_ii(), paper_points::table6_case_ii()),
    ];

    // (label, tiles, hop_stats evals/sec, search wall secs, canonical ns,
    //  optimized ns)
    let mut rows: Vec<(String, usize, f64, f64, f64, f64)> = Vec::new();
    for (name, space, action) in &cases {
        let p = space.decode(action);
        let layout = Placement::canonical(p.n_footprints(), &p.hbm_locs());

        let mut runner = Runner::new();
        runner.bench(&format!("{name}: hop_stats ({} tiles)", p.n_footprints()), || {
            std::hint::black_box(layout.hop_stats());
        });
        let stats_ns = runner.results().last().unwrap().ns_per_iter.mean;
        let evals_per_sec = 1e9 / stats_ns;

        let cfg = PlaceConfig { driver: DriverConfig::greedy_with_budget(budget), seed: 0 };
        let mut canonical_ns = 0.0;
        let mut optimized_ns = 0.0;
        let mut quick = Runner::quick();
        quick.bench(&format!("{name}: optimize_placement ({budget}-eval greedy)"), || {
            let out = optimize_placement(space, &calib, &p, &cfg);
            canonical_ns = out.canonical_ns;
            optimized_ns = out.optimized_ns;
            std::hint::black_box(out.placement.hbm.len());
        });
        let search_secs = quick.results().last().unwrap().ns_per_iter.mean / 1e9;

        println!(
            "{name:>8}: hop_stats {} ({evals_per_sec:.0} evals/s), \
             search {search_secs:.3}s, comm {canonical_ns:.2} -> {optimized_ns:.2} ns",
            fmt_ns(stats_ns)
        );
        rows.push((
            name.to_string(),
            p.n_footprints(),
            evals_per_sec,
            search_secs,
            canonical_ns,
            optimized_ns,
        ));
    }

    // Batched attach-point scoring: the kernel-layer HopField (per-tile
    // distance table, built once per occupied-tile set) vs the full
    // O(tiles × HBM) coordinate rescan per candidate. Both paths score
    // the same random candidate attach sets; identity is asserted
    // before timing.
    let meshes = [(5usize, 6usize), (8, 16), (12, 12)];
    let n_candidates = 64usize;
    // (label, tiles, scan ns/score, batched ns/score)
    let mut score_rows: Vec<(String, usize, f64, f64)> = Vec::new();
    let mut rng = Rng::new(7);
    for &(m, n) in &meshes {
        let proto = grid_placement(m, n, 4, &mut rng);
        let ai = proto.hop_stats();
        let field = HopField::new(m, n, &proto.tiles);
        let candidates: Vec<Vec<HbmAttach>> = (0..n_candidates)
            .map(|_| grid_placement(m, n, 4, &mut rng).hbm)
            .collect();
        let cells: Vec<Vec<(usize, usize)>> = candidates
            .iter()
            .map(|c| c.iter().map(|a| (a.tile.0 * n + a.tile.1, a.extra_hops)).collect())
            .collect();
        // identity: table lookup == coordinate scan, bit for bit
        let mut scan = proto.clone();
        for (cand, cell) in candidates.iter().zip(cells.iter()) {
            scan.hbm = cand.clone();
            let want = scan.hop_stats_with_ai(&ai);
            let (max_hbm, mean_hbm) = field.hbm_stats(cell);
            assert_eq!(max_hbm, want.max_hbm_hops, "{m}x{n} batched max diverged");
            assert_eq!(
                mean_hbm.to_bits(),
                want.mean_hbm_hops.to_bits(),
                "{m}x{n} batched mean diverged"
            );
        }

        let label = format!("{m}x{n}");
        let mut runner = Runner::new();
        runner.bench(&format!("{label}: scan scoring ({n_candidates} candidates)"), || {
            for cand in &candidates {
                scan.hbm.clone_from(cand);
                std::hint::black_box(scan.hop_stats_with_ai(&ai));
            }
        });
        let scan_ns =
            runner.results().last().unwrap().ns_per_iter.mean / n_candidates as f64;
        runner.bench(&format!("{label}: batched scoring ({n_candidates} candidates)"), || {
            for cell in &cells {
                std::hint::black_box(field.hbm_stats(cell));
            }
        });
        let batched_ns =
            runner.results().last().unwrap().ns_per_iter.mean / n_candidates as f64;
        println!(
            "{label:>8}: score {} -> {} per candidate ({:.1}x)",
            fmt_ns(scan_ns),
            fmt_ns(batched_ns),
            scan_ns / batched_ns
        );
        score_rows.push((label, m * n, scan_ns, batched_ns));
    }

    let mut csv = report::csv(
        "perf_place.csv",
        &[
            "case",
            "tiles",
            "hop_stats_evals_per_sec",
            "search_secs",
            "canonical_comm_ns",
            "optimized_comm_ns",
        ],
    );
    for (name, tiles, eps, secs, can, opt) in &rows {
        csv.labeled_row(name, &[*tiles as f64, *eps, *secs, *can, *opt]).expect("csv row");
    }
    csv.flush().expect("csv flush");

    // BENCH_place.json: the machine-readable perf-trajectory seed.
    let mut json = String::from("{\n  \"budget\": ");
    json.push_str(&budget.to_string());
    json.push_str(",\n  \"cases\": {\n");
    for (i, (name, tiles, eps, secs, can, opt)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    \"{name}\": {{\"tiles\": {tiles}, \"hop_stats_evals_per_sec\": {eps:.1}, \
             \"search_secs\": {secs:.4}, \"canonical_comm_ns\": {can:.4}, \
             \"optimized_comm_ns\": {opt:.4}}}{}\n",
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  },\n  \"batched_scoring\": {\n");
    for (i, (label, tiles, scan_ns, batched_ns)) in score_rows.iter().enumerate() {
        json.push_str(&format!(
            "    \"{label}\": {{\"tiles\": {tiles}, \"scan_score_ns\": {scan_ns:.1}, \
             \"batched_score_ns\": {batched_ns:.1}, \"batched_speedup\": {:.2}, \
             \"batched_scores_per_sec\": {:.1}}}{}\n",
            scan_ns / batched_ns,
            1e9 / batched_ns,
            if i + 1 < score_rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  }\n}\n");
    let path = report::write_text("BENCH_place.json", &json);
    println!("wrote {}", path.display());

    let mut fresh: Vec<(String, f64)> = rows
        .iter()
        .map(|(name, _, eps, ..)| (format!("cases.{name}.hop_stats_evals_per_sec"), *eps))
        .collect();
    fresh.extend(
        score_rows
            .iter()
            .map(|(l, _, _, b)| (format!("batched_scoring.{l}.batched_scores_per_sec"), 1e9 / b)),
    );
    enforce_throughput_baseline("perf_place", baseline.as_deref(), &fresh, REGRESSION_TOLERANCE);
}

//! Fig. 7: impact of episode length on PPO convergence.
//!
//! Trains PPO agents at episode length 2 and 10 and reports (a) the mean
//! episodic reward and (b) the cost-model value
//! (= mean_episodic_reward / episode_length). The paper's observation:
//! longer episodes inflate the episodic reward but *not* the cost-model
//! value — exploitation wins over exploration.
//!
//! Quick mode (default) trains 32K steps; set CHIPLET_GYM_FULL=1 for the
//! paper's 250K. Emits `bench_results/fig7_episode_len.csv`.

use chiplet_gym::gym::ChipletGymEnv;
use chiplet_gym::report;
use chiplet_gym::rl::{train_ppo, PpoConfig};
use chiplet_gym::runtime::Engine;

fn main() {
    let engine = match Engine::discover() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP fig7 (artifacts missing): {e:#}");
            return;
        }
    };
    let full = std::env::var("CHIPLET_GYM_FULL").is_ok();
    let timesteps = if full { 250_000 } else { 32_768 };

    let mut csv = report::csv(
        "fig7_episode_len.csv",
        &["episode_len", "timesteps", "ep_rew_mean", "cost_value"],
    );
    let mut finals = Vec::new();
    for &ep_len in &[2usize, 10] {
        let mut cfg = PpoConfig::from_manifest(&engine);
        cfg.total_timesteps = timesteps;
        cfg.episode_len = ep_len;
        let mut env = ChipletGymEnv::case_i();
        let t0 = std::time::Instant::now();
        let trace = train_ppo(&engine, &mut env, &cfg, 0).expect("ppo");
        for s in &trace.history {
            csv.row(&[
                ep_len as f64,
                s.timesteps as f64,
                s.ep_rew_mean,
                s.cost_value,
            ])
            .unwrap();
        }
        let last = trace.history.last().unwrap();
        println!(
            "episode_len {ep_len:>2}: {} steps in {:.1}s -> ep_rew_mean {:.1}, cost_value {:.1}, best {:.1}",
            timesteps,
            t0.elapsed().as_secs_f64(),
            last.ep_rew_mean,
            last.cost_value,
            trace.best_reward
        );
        finals.push((ep_len, last.ep_rew_mean, last.cost_value));
    }
    csv.flush().unwrap();

    let (l2, r2, c2) = finals[0];
    let (l10, r10, c10) = finals[1];
    println!("\npaper shape (Fig. 7): ep-len {l10} episodic reward ({r10:.0}) should");
    println!("exceed ep-len {l2}'s ({r2:.0}) by roughly the episode-length ratio, while");
    println!("the cost-model values stay comparable: {c2:.1} vs {c10:.1}");
    println!("wrote {}", report::result_path("fig7_episode_len.csv").display());
}

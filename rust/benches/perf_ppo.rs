//! Native-PPO throughput: environment steps/sec of the dynamic
//! action-space training loop.
//!
//! Times `train_ppo_native` (rollout + GAE + minibatch Adam updates,
//! all pure Rust — no artifacts needed) across the four cells of the
//! {14-head canonical, 15-head learned-placement} × {sequential n_envs
//! 1, batched n_envs 4} grid, so the cost of the placement head and the
//! benefit of batched rollouts are both on the record. Writes
//! `BENCH_ppo.json` (plus a CSV of the rows) under `bench_results/`,
//! seeding the RL perf trajectory across PRs.

use chiplet_gym::cost::Calib;
use chiplet_gym::gym::ChipletGymEnv;
use chiplet_gym::model::space::DesignSpace;
use chiplet_gym::report;
use chiplet_gym::rl::{train_ppo_native, PpoConfig};
use chiplet_gym::util::bench::{enforce_throughput_baseline, REGRESSION_TOLERANCE};

fn bench_cfg() -> PpoConfig {
    let mut cfg = PpoConfig::paper();
    cfg.total_timesteps = 1_024;
    cfg.n_steps = 512;
    cfg.batch_size = 64;
    cfg.n_epoch = 4;
    cfg
}

fn main() {
    let baseline = std::fs::read_to_string(report::result_path("BENCH_ppo.json")).ok();
    let full = std::env::var("CHIPLET_GYM_FULL").is_ok();
    let mut cfg = bench_cfg();
    if full {
        cfg.total_timesteps = 16_384;
        cfg.n_steps = 2_048;
    }
    let calib = Calib::default();

    let cases = [
        ("14-head", DesignSpace::case_i()),
        ("15-head", DesignSpace::case_i().with_placement_head()),
    ];
    let widths = [("sequential", 1usize), ("batched", 4usize)];

    // (label, heads, n_envs, steps/sec, best reward)
    let mut rows: Vec<(String, usize, usize, f64, f64)> = Vec::new();
    for (case, space) in &cases {
        for (mode, n_envs) in &widths {
            let mut run_cfg = cfg;
            run_cfg.n_envs = *n_envs;
            assert_eq!(run_cfg.n_steps % n_envs, 0);
            let mut env = ChipletGymEnv::new(*space, calib.clone(), run_cfg.episode_len);
            let t0 = std::time::Instant::now();
            let trace = train_ppo_native(&mut env, &run_cfg, 0).expect("native ppo");
            let secs = t0.elapsed().as_secs_f64();
            let sps = trace.timesteps as f64 / secs;
            println!(
                "{case:>8} {mode:>10} (n_envs {n_envs}): {} steps in {secs:.2}s \
                 = {sps:.0} steps/s, best {:.2}",
                trace.timesteps, trace.best_reward
            );
            rows.push((
                format!("{case}/{mode}"),
                space.layout().n_heads(),
                *n_envs,
                sps,
                trace.best_reward,
            ));
        }
    }

    let mut csv = report::csv(
        "perf_ppo.csv",
        &["config", "heads", "n_envs", "steps_per_sec", "best_reward"],
    );
    for (label, heads, n_envs, sps, best) in &rows {
        csv.labeled_row(label, &[*heads as f64, *n_envs as f64, *sps, *best])
            .expect("csv row");
    }
    csv.flush().expect("csv flush");

    // BENCH_ppo.json: the machine-readable RL perf-trajectory seed.
    let mut json = String::from("{\n  \"timesteps\": ");
    json.push_str(&cfg.total_timesteps.to_string());
    json.push_str(",\n  \"configs\": {\n");
    for (i, (label, heads, n_envs, sps, best)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    \"{label}\": {{\"heads\": {heads}, \"n_envs\": {n_envs}, \
             \"steps_per_sec\": {sps:.1}, \"best_reward\": {best:.4}}}{}\n",
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  }\n}\n");
    let path = report::write_text("BENCH_ppo.json", &json);
    println!("wrote {}", path.display());

    // Short-timestep runs are noisier than micro-benches, but a >25%
    // steps/sec drop on any cell still means a hot-path regression.
    let fresh: Vec<(String, f64)> = rows
        .iter()
        .map(|(label, _, _, sps, _)| (format!("configs.{label}.steps_per_sec"), *sps))
        .collect();
    enforce_throughput_baseline("perf_ppo", baseline.as_deref(), &fresh, REGRESSION_TOLERANCE);
}

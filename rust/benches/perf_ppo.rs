//! Native-PPO throughput: environment steps/sec of the dynamic
//! action-space training loop.
//!
//! Times `train_ppo_native` (rollout + GAE + minibatch Adam updates,
//! all pure Rust — no artifacts needed) across the cells of the
//! {14-head canonical, 15-head learned-placement} × {sequential n_envs
//! 1, batched n_envs 4, data-parallel n_envs 4 + jobs 4} grid, so the
//! cost of the placement head, the benefit of batched rollouts, and the
//! worker-pool speedup (`PpoConfig::jobs` — bit-identical results, see
//! `tests/parallel_determinism.rs`) are all on the record. Writes
//! `BENCH_ppo.json` (plus a CSV of the rows) under `bench_results/`,
//! seeding the RL perf trajectory across PRs.

use chiplet_gym::cost::Calib;
use chiplet_gym::gym::ChipletGymEnv;
use chiplet_gym::model::space::DesignSpace;
use chiplet_gym::report;
use chiplet_gym::rl::{train_ppo_native, PpoConfig};
use chiplet_gym::util::bench::{enforce_throughput_baseline, REGRESSION_TOLERANCE};

fn bench_cfg() -> PpoConfig {
    let mut cfg = PpoConfig::paper();
    cfg.total_timesteps = 1_024;
    cfg.n_steps = 512;
    cfg.batch_size = 64;
    cfg.n_epoch = 4;
    cfg
}

fn main() {
    let baseline = std::fs::read_to_string(report::result_path("BENCH_ppo.json")).ok();
    let full = std::env::var("CHIPLET_GYM_FULL").is_ok();
    let mut cfg = bench_cfg();
    if full {
        cfg.total_timesteps = 16_384;
        cfg.n_steps = 2_048;
    }
    let calib = Calib::default();

    let cases = [
        ("14-head", DesignSpace::case_i()),
        ("15-head", DesignSpace::case_i().with_placement_head()),
    ];
    // (mode, n_envs, jobs): the original serial/batched cells keep
    // their labels (and baseline keys) unchanged; the threads axis adds
    // jobs-1 and jobs-4 cells on the batched rollout shape.
    let widths = [
        ("sequential", 1usize, 1usize),
        ("batched", 4, 1),
        ("batched-j4", 4, 4),
    ];

    // (label, heads, n_envs, jobs, steps/sec, best reward)
    let mut rows: Vec<(String, usize, usize, usize, f64, f64)> = Vec::new();
    for (case, space) in &cases {
        for (mode, n_envs, jobs) in &widths {
            let mut run_cfg = cfg;
            run_cfg.n_envs = *n_envs;
            run_cfg.jobs = *jobs;
            assert_eq!(run_cfg.n_steps % n_envs, 0);
            let mut env = ChipletGymEnv::new(*space, calib.clone(), run_cfg.episode_len);
            let t0 = std::time::Instant::now();
            let trace = train_ppo_native(&mut env, &run_cfg, 0).expect("native ppo");
            let secs = t0.elapsed().as_secs_f64();
            let sps = trace.timesteps as f64 / secs;
            println!(
                "{case:>8} {mode:>10} (n_envs {n_envs}, jobs {jobs}): {} steps in {secs:.2}s \
                 = {sps:.0} steps/s, best {:.2}",
                trace.timesteps, trace.best_reward
            );
            rows.push((
                format!("{case}/{mode}"),
                space.layout().n_heads(),
                *n_envs,
                *jobs,
                sps,
                trace.best_reward,
            ));
        }
    }

    // The acceptance headline: data-parallel speedup on the 15-head
    // cell (results are bit-identical by construction, so this is free
    // throughput). Printed, not asserted — CI runners vary in cores.
    let sps_of = |label: &str| rows.iter().find(|r| r.0 == label).map(|r| r.4);
    if let (Some(j1), Some(j4)) = (sps_of("15-head/batched"), sps_of("15-head/batched-j4")) {
        println!("15-head jobs-4 speedup over jobs-1 (n_envs 4): {:.2}x", j4 / j1);
    }

    let mut csv = report::csv(
        "perf_ppo.csv",
        &["config", "heads", "n_envs", "jobs", "steps_per_sec", "best_reward"],
    );
    for (label, heads, n_envs, jobs, sps, best) in &rows {
        csv.labeled_row(label, &[*heads as f64, *n_envs as f64, *jobs as f64, *sps, *best])
            .expect("csv row");
    }
    csv.flush().expect("csv flush");

    // BENCH_ppo.json: the machine-readable RL perf-trajectory seed.
    let mut json = String::from("{\n  \"timesteps\": ");
    json.push_str(&cfg.total_timesteps.to_string());
    json.push_str(",\n  \"configs\": {\n");
    for (i, (label, heads, n_envs, jobs, sps, best)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    \"{label}\": {{\"heads\": {heads}, \"n_envs\": {n_envs}, \"jobs\": {jobs}, \
             \"steps_per_sec\": {sps:.1}, \"best_reward\": {best:.4}}}{}\n",
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  }\n}\n");
    let path = report::write_text("BENCH_ppo.json", &json);
    println!("wrote {}", path.display());

    // Short-timestep runs are noisier than micro-benches, but a >25%
    // steps/sec drop on any cell still means a hot-path regression.
    let fresh: Vec<(String, f64)> = rows
        .iter()
        .map(|(label, _, _, _, sps, _)| (format!("configs.{label}.steps_per_sec"), *sps))
        .collect();
    enforce_throughput_baseline("perf_ppo", baseline.as_deref(), &fresh, REGRESSION_TOLERANCE);
}

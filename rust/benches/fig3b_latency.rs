//! Fig. 3(b): normalized communication latency vs number of chiplets
//! (2D mesh, worst-case source-destination pair).
//!
//! Emits `bench_results/fig3b_latency.csv`.

use chiplet_gym::mesh::grid::MeshGrid;
use chiplet_gym::mesh::latency::{comm_latency_ns, LatencyParams};
use chiplet_gym::model::space::HbmLoc;
use chiplet_gym::report;
use chiplet_gym::util::bench::Runner;
use chiplet_gym::util::table::Table;

fn main() {
    let params = LatencyParams::d25();
    let counts = [1usize, 2, 4, 8, 16, 32, 64, 96, 128];
    let base = {
        let g = MeshGrid::new(1, &[HbmLoc::Left]);
        comm_latency_ns(&params, g.max_ai_hops().max(1), 20.0, 1000)
    };

    let mut csv = report::csv(
        "fig3b_latency.csv",
        &["n_chiplets", "mesh_m", "mesh_n", "max_hops", "latency_ns", "normalized"],
    );
    let mut t = Table::new(["chiplets", "mesh", "max hops", "latency (ns)", "normalized"]);
    for &n in &counts {
        let g = MeshGrid::new(n, &[HbmLoc::Left]);
        let hops = g.max_ai_hops().max(1);
        let l = comm_latency_ns(&params, hops, 20.0, 1000);
        csv.row(&[
            n as f64,
            g.m as f64,
            g.n as f64,
            hops as f64,
            l,
            l / base,
        ])
        .unwrap();
        t.row([
            format!("{n}"),
            format!("{}x{}", g.m, g.n),
            format!("{hops}"),
            format!("{l:.2}"),
            format!("{:.2}", l / base),
        ]);
    }
    csv.flush().unwrap();
    t.print();
    println!(
        "\nshape check: latency grows ~sqrt(n) — 128 chiplets is {:.1}x of 1",
        comm_latency_ns(
            &params,
            MeshGrid::new(128, &[HbmLoc::Left]).max_ai_hops(),
            20.0,
            1000
        ) / base
    );

    let mut runner = Runner::new();
    runner.bench("MeshGrid::new(128) + max hops", || {
        let g = MeshGrid::new(std::hint::black_box(128), &[HbmLoc::Left]);
        std::hint::black_box(g.max_hbm_hops());
    });
    println!("\n{}", runner.report());
    println!("wrote {}", report::result_path("fig3b_latency.csv").display());
}

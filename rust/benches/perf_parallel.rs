//! Parallel Algorithm-1 wall-clock: 1-thread vs N-thread SA fan-out.
//!
//! The paper runs "20 SAs and 20 trained RL agents ... around 10 mins"
//! sequentially; `opt::parallel` shards the SA seeds across
//! `available_parallelism` workers with bit-identical output. This bench
//! times the same 8-seed SA-only Alg. 1 at `--jobs 1` and `--jobs 0`
//! (all cores), prints the speedup, and re-checks output equality.

use chiplet_gym::cost::Calib;
use chiplet_gym::model::space::DesignSpace;
use chiplet_gym::opt::parallel::{sa_only_optimize_par, worker_count};
use chiplet_gym::opt::sa::SaConfig;
use chiplet_gym::report;
use chiplet_gym::util::bench::{fmt_ns, Runner};

fn main() {
    let space = DesignSpace::case_i();
    let calib = Calib::default();
    let sa = SaConfig {
        iterations: 20_000,
        trace_every: 0,
        ..SaConfig::default()
    };
    let seeds: Vec<u64> = (0..8).collect();
    let jobs = worker_count(0, seeds.len());

    let mut runner = Runner::quick();
    runner.bench("Alg.1 SA-only, 8 seeds, --jobs 1", || {
        std::hint::black_box(sa_only_optimize_par(space, &calib, &sa, &seeds, 1));
    });
    let par_name = format!("Alg.1 SA-only, 8 seeds, --jobs {jobs}");
    runner.bench(&par_name, || {
        std::hint::black_box(sa_only_optimize_par(space, &calib, &sa, &seeds, 0));
    });
    println!("{}", runner.report());

    let seq_ns = runner.results()[0].ns_per_iter.mean;
    let par_ns = runner.results()[1].ns_per_iter.mean;
    let speedup = seq_ns / par_ns;
    println!(
        "sequential {} vs {jobs}-thread {} => speedup {speedup:.2}x",
        fmt_ns(seq_ns),
        fmt_ns(par_ns)
    );

    // The speedup must never come at the cost of determinism.
    let sequential = sa_only_optimize_par(space, &calib, &sa, &seeds, 1);
    let parallel = sa_only_optimize_par(space, &calib, &sa, &seeds, 0);
    assert_eq!(sequential.best.action, parallel.best.action);
    assert_eq!(sequential.best.seed, parallel.best.seed);
    assert_eq!(
        sequential.best.eval.reward.to_bits(),
        parallel.best.eval.reward.to_bits()
    );
    println!(
        "determinism check OK: best = {} seed {} @ {:.2}",
        parallel.best.source, parallel.best.seed, parallel.best.eval.reward
    );

    report::write_text(
        "perf_parallel.txt",
        &format!(
            "{}\njobs={jobs}\nspeedup={speedup:.3}\n",
            runner.report()
        ),
    );
}

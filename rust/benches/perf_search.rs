//! Search-core throughput: evaluations/sec per portfolio optimizer.
//!
//! Runs each non-RL driver (SA, random, GA, greedy) through one
//! fixed-budget case-(i) search, times the run with the criterion-lite
//! harness, and reports objective evaluations per second — the metric
//! that tells you how much of the wall-clock is driver overhead vs the
//! PPAC evaluator itself. Writes `BENCH_search.json` (plus a CSV of the
//! per-driver rows) under `bench_results/` to seed the perf trajectory
//! across PRs.

use chiplet_gym::cost::Calib;
use chiplet_gym::model::space::DesignSpace;
use chiplet_gym::opt::sa::SaConfig;
use chiplet_gym::opt::search::{CostObjective, DriverConfig, GaConfig};
use chiplet_gym::report;
use chiplet_gym::util::bench::{fmt_ns, Runner};

fn main() {
    let space = DesignSpace::case_i();
    let calib = Calib::default();
    let budget = 20_000usize;

    let sa = SaConfig { iterations: budget, trace_every: 0, ..SaConfig::default() };
    let cases: Vec<(&str, DriverConfig)> = vec![
        ("SA", DriverConfig::Sa(sa)),
        ("random", DriverConfig::random_with_budget(budget)),
        ("GA", DriverConfig::Ga(GaConfig::with_budget(budget))),
        ("greedy", DriverConfig::greedy_with_budget(budget)),
    ];

    let mut runner = Runner::quick();
    // (name, evals per run, evals/sec, best reward at seed 0)
    let mut rows: Vec<(String, usize, f64, f64)> = Vec::new();
    for (name, driver) in &cases {
        let mut evals = 0usize;
        let mut best = f64::NEG_INFINITY;
        runner.bench(&format!("{name}: one {budget}-eval run"), || {
            let mut obj = CostObjective::new(&space, &calib);
            let t = driver.run(&space, &mut obj, 0);
            evals = t.evaluations;
            best = t.best_eval.reward;
            std::hint::black_box(t.best_action);
        });
        let ns = runner.results().last().unwrap().ns_per_iter.mean;
        let evals_per_sec = evals as f64 * 1e9 / ns;
        println!(
            "{name:>7}: {evals} evals in {} => {evals_per_sec:.0} evals/s, best {best:.2}",
            fmt_ns(ns)
        );
        rows.push((name.to_string(), evals, evals_per_sec, best));
    }
    println!("{}", runner.report());

    let mut csv = report::csv("perf_search.csv", &["driver", "evals", "evals_per_sec", "best"]);
    for (name, evals, eps, best) in &rows {
        csv.labeled_row(name, &[*evals as f64, *eps, *best]).expect("csv row");
    }
    csv.flush().expect("csv flush");

    // BENCH_search.json: the machine-readable perf-trajectory seed.
    let mut json = String::from("{\n  \"budget\": ");
    json.push_str(&budget.to_string());
    json.push_str(",\n  \"optimizers\": {\n");
    for (i, (name, evals, eps, best)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    \"{name}\": {{\"evals\": {evals}, \"evals_per_sec\": {eps:.1}, \
             \"best_reward\": {best:.4}}}{}\n",
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  }\n}\n");
    let path = report::write_text("BENCH_search.json", &json);
    println!("wrote {}", path.display());
}

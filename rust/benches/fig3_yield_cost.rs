//! Fig. 3(a): yield (left axis) and normalized cost per yielded area
//! (right axis) vs die area, at several tech nodes.
//!
//! Regenerates the exact curves the paper uses to justify the 400 mm²
//! per-chiplet cap. Emits `bench_results/fig3a_yield_cost.csv` and prints
//! the series; also times the yield evaluation itself.

use chiplet_gym::cost::yield_model::{
    cost_per_yielded_area, die_yield, node_defect_density,
};
use chiplet_gym::report;
use chiplet_gym::util::bench::Runner;
use chiplet_gym::util::table::Table;

fn main() {
    let nodes = [14u32, 10, 7];
    let alpha = 4.0;
    let areas: Vec<f64> = (1..=16).map(|i| i as f64 * 50.0).collect();

    let mut csv = report::csv(
        "fig3a_yield_cost.csv",
        &["area_mm2", "node_nm", "yield", "norm_cost_per_yielded_area"],
    );
    let mut table = Table::new(["area (mm2)", "14nm Y", "10nm Y", "7nm Y", "7nm cost"]);
    for &a in &areas {
        let mut row = vec![format!("{a}")];
        let mut cost7 = 0.0;
        for &node in &nodes {
            let d = node_defect_density(node);
            let y = die_yield(a, d, alpha);
            let c = cost_per_yielded_area(a, d, alpha, 1.0);
            if node == 7 {
                cost7 = c;
            }
            csv.row(&[a, node as f64, y, c]).unwrap();
            row.push(format!("{y:.3}"));
        }
        row.push(format!("{cost7:.3}"));
        table.row(row);
    }
    csv.flush().unwrap();
    table.print();

    // Paper checkpoints
    println!("\npaper checkpoints (7nm, alpha 4):");
    println!(
        "  Y(826mm2) = {:.3}  (paper: 0.48)",
        die_yield(826.0, node_defect_density(7), alpha)
    );
    println!(
        "  Y(26mm2)  = {:.3}  (paper: 0.97)",
        die_yield(26.0, node_defect_density(7), alpha)
    );
    println!(
        "  Y(14mm2)  = {:.3}  (paper: 0.98)",
        die_yield(14.0, node_defect_density(7), alpha)
    );

    let mut runner = Runner::new();
    runner.bench("die_yield(400mm2)", || {
        std::hint::black_box(die_yield(
            std::hint::black_box(400.0),
            node_defect_density(7),
            alpha,
        ));
    });
    println!("\n{}", runner.report());
    println!("wrote {}", report::result_path("fig3a_yield_cost.csv").display());
}

//! Fig. 12: MLPerf comparison of the 60-chiplet and 112-chiplet systems
//! against the monolithic GPU — (a) inferences/sec, (b) inferences/joule,
//! (c) die + package cost. Table 7 features are printed as the preamble.
//!
//! Paper headline: 1.52× throughput, 3.7×/3.6× energy efficiency, 76×/143×
//! cheaper dies, 1.62×/2.46× package cost. Emits
//! `bench_results/fig12_mlperf.csv`.

use chiplet_gym::cost::{evaluate, Calib};
use chiplet_gym::model::space::{paper_points, DesignSpace};
use chiplet_gym::report;
use chiplet_gym::util::table::{fnum, Table};
use chiplet_gym::workloads::{mapping, mlperf::mlperf_suite, Monolithic};

fn main() {
    let calib = Calib::default();
    let suite = mlperf_suite();

    // ---- Table 7 preamble ----
    let mut t7 = Table::new(["model", "domain", "dataset", "GFLOPs/task"]);
    for w in &suite {
        t7.row([
            w.name.to_string(),
            w.domain.to_string(),
            w.dataset.to_string(),
            format!("{}", w.gflops_per_task),
        ]);
    }
    println!("Table 7 benchmark features:");
    t7.print();

    let mono = Monolithic::new(&calib);
    let sys60 = DesignSpace::case_i().decode(&paper_points::table6_case_i());
    let sys112 = DesignSpace::case_ii().decode(&paper_points::table6_case_ii());
    let e60 = evaluate(&calib, &sys60);
    let e112 = evaluate(&calib, &sys112);

    let mut csv = report::csv(
        "fig12_mlperf.csv",
        &["benchmark", "system", "inf_per_sec", "inf_per_joule"],
    );
    let mut ta = Table::new([
        "benchmark", "mono inf/s", "60c inf/s", "112c inf/s", "60c speedup", "112c speedup",
    ]);
    let mut tb = Table::new([
        "benchmark", "mono inf/J", "60c inf/J", "112c inf/J", "60c gain", "112c gain",
    ]);

    let mut speed60 = Vec::new();
    let mut gain60 = Vec::new();
    for w in &suite {
        let m_rate = mono.tasks_per_sec(&calib, w);
        let m_eff = mono.tasks_per_joule(w);
        let mut rates = Vec::new();
        let mut effs = Vec::new();
        for (sys, e) in [(&sys60, &e60), (&sys112, &e112)] {
            let u = mapping::u_chip(e.pe_per_chiplet, sys.n_chiplets, w);
            let tops = e.throughput_tops / calib.default_u_chip * u;
            let rate = tops * 1e12 / (w.gmac_per_task() * 1e9);
            let eff = 1.0 / (e.e_op_pj * w.gmac_per_task() * 1e-3);
            rates.push(rate);
            effs.push(eff);
        }
        csv.row_str(&[w.name.into(), "mono".into(), format!("{m_rate}"), format!("{m_eff}")]).unwrap();
        csv.row_str(&[w.name.into(), "60-chiplet".into(), format!("{}", rates[0]), format!("{}", effs[0])]).unwrap();
        csv.row_str(&[w.name.into(), "112-chiplet".into(), format!("{}", rates[1]), format!("{}", effs[1])]).unwrap();
        ta.row([
            w.name.to_string(), fnum(m_rate), fnum(rates[0]), fnum(rates[1]),
            format!("{:.2}x", rates[0] / m_rate), format!("{:.2}x", rates[1] / m_rate),
        ]);
        tb.row([
            w.name.to_string(), fnum(m_eff), fnum(effs[0]), fnum(effs[1]),
            format!("{:.2}x", effs[0] / m_eff), format!("{:.2}x", effs[1] / m_eff),
        ]);
        speed60.push(rates[0] / m_rate);
        gain60.push(effs[0] / m_eff);
    }
    csv.flush().unwrap();

    println!("\nFig. 12(a) inferences/sec:");
    ta.print();
    println!("\nFig. 12(b) inferences/joule:");
    tb.print();

    println!("\nFig. 12(c) cost:");
    let mut tc = Table::new(["system", "die cost", "pkg cost", "die vs mono", "pkg vs mono"]);
    tc.row(["monolithic".to_string(), fnum(mono.die_cost), fnum(mono.pkg_cost), "1.00x".into(), "1.00x".into()]);
    for (name, e) in [("60-chiplet", &e60), ("112-chiplet", &e112)] {
        tc.row([
            name.to_string(), fnum(e.die_cost), fnum(e.pkg_cost),
            format!("{:.4}x", e.die_cost / mono.die_cost),
            format!("{:.2}x", e.pkg_cost / mono.pkg_cost),
        ]);
    }
    tc.print();

    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
    println!("\nheadline vs paper:");
    println!("{}", report::compare_line("  throughput gain (60c)", 1.52, mean(&speed60)));
    println!("{}", report::compare_line("  energy-eff gain (60c)", 3.7, mean(&gain60)));
    println!("{}", report::compare_line("  die cost ratio (mono/60c)", 76.0, mono.die_cost / e60.die_cost));
    println!("{}", report::compare_line("  die cost ratio (mono/112c)", 143.0, mono.die_cost / e112.die_cost));
    println!("{}", report::compare_line("  pkg cost ratio (60c/mono)", 1.62, e60.pkg_cost / mono.pkg_cost));
    println!("{}", report::compare_line("  pkg cost ratio (112c/mono)", 2.46, e112.pkg_cost / mono.pkg_cost));
    println!("wrote {}", report::result_path("fig12_mlperf.csv").display());
}

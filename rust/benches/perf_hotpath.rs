//! Hot-path micro-benchmarks — the §Perf numbers of EXPERIMENTS.md.
//!
//! * `evaluate(design_point)` — the SA inner loop (paper: 500K iters
//!   < 1 min ⇒ ≥ 8.3K evals/s; target here: > 1M/s).
//! * SA end-to-end iterations/sec.
//! * `policy_forward` HLO call — the PPO rollout inner loop.
//! * `ppo_update` HLO call — the PPO optimize inner loop.
//! * One full PPO iteration (2048 rollout steps + 320 updates).

use chiplet_gym::cost::{evaluate, Calib};
use chiplet_gym::gym::ChipletGymEnv;
use chiplet_gym::model::space::DesignSpace;
use chiplet_gym::opt::sa::{simulated_annealing, SaConfig};
use chiplet_gym::report;
use chiplet_gym::rl::{train_ppo, PpoConfig};
use chiplet_gym::runtime::Engine;
use chiplet_gym::util::bench::Runner;
use chiplet_gym::util::Rng;

fn main() {
    let calib = Calib::default();
    let space = DesignSpace::case_i();
    let mut runner = Runner::new();

    // ---- L3: evaluate() ----
    let mut rng = Rng::new(0);
    let points: Vec<_> = (0..1024)
        .map(|_| space.decode(&space.random_action(&mut rng)))
        .collect();
    let mut i = 0;
    runner.bench("L3 evaluate(design_point)", || {
        let p = &points[i & 1023];
        i += 1;
        std::hint::black_box(evaluate(&calib, p));
    });

    // ---- L3: SA end-to-end ----
    let sa_cfg = SaConfig { iterations: 10_000, trace_every: 0, ..SaConfig::default() };
    runner.bench("L3 SA 10K iterations", || {
        std::hint::black_box(simulated_annealing(&space, &calib, &sa_cfg, 7));
    });

    // ---- L2/L1: HLO calls ----
    if let Ok(engine) = Engine::discover() {
        let params = engine.golden_params().expect("golden params");
        let obs = vec![0.1f32; engine.manifest.obs_dim];
        runner.bench("L2/L1 policy_forward (HLO, params upload)", || {
            std::hint::black_box(engine.policy_forward(&params, &obs).unwrap());
        });
        let session = engine.forward_session(&params).unwrap();
        runner.bench("L2/L1 policy_forward (HLO, cached params)", || {
            std::hint::black_box(session.forward(&obs).unwrap());
        });

        let m = &engine.manifest;
        let mb = m.hyper.batch_size;
        let obs_b = vec![0.1f32; mb * m.obs_dim];
        let mut act = vec![0i32; mb * m.n_heads];
        for (k, a) in act.iter_mut().enumerate() {
            *a = (k % 2) as i32;
        }
        let vecs = vec![0.1f32; mb];
        let zeros = vec![0f32; params.len()];
        runner.bench("L2 ppo_update (HLO)", || {
            std::hint::black_box(
                engine
                    .ppo_update(
                        &params, &zeros, &zeros, 1.0, &obs_b, &act, &vecs, &vecs,
                        &vecs, [3e-4, 0.2, 0.1],
                    )
                    .unwrap(),
            );
        });

        // ---- epoch-fused optimize phase ----
        if engine.has_epochs() {
            let n = m.hyper.n_steps;
            let k = m.hyper.n_epoch * (n / mb);
            let obs_n = vec![0.1f32; n * m.obs_dim];
            let mut act_n = vec![0i32; n * m.n_heads];
            for (i, a) in act_n.iter_mut().enumerate() {
                *a = (i % 2) as i32;
            }
            let vec_n = vec![0.1f32; n];
            let mut perm = vec![0i32; k * mb];
            for (i, p) in perm.iter_mut().enumerate() {
                *p = (i % n) as i32;
            }
            let mut quick = Runner::quick();
            quick.bench("L2 ppo_epochs (320 fused minibatches)", || {
                std::hint::black_box(
                    engine
                        .ppo_epochs(
                            &params, &zeros, &zeros, 1.0, &obs_n, &act_n, &vec_n,
                            &vec_n, &vec_n, &perm, [3e-4, 0.2, 0.1],
                        )
                        .unwrap(),
                );
            });
            println!("{}", quick.report());
        }

        // ---- full PPO iteration ----
        let mut quick = Runner::quick();
        let mut cfg = PpoConfig::from_manifest(&engine);
        cfg.total_timesteps = cfg.n_steps; // exactly one iteration
        quick.bench("PPO one iteration (2048 steps + 320 updates)", || {
            let mut env = ChipletGymEnv::case_i();
            std::hint::black_box(train_ppo(&engine, &mut env, &cfg, 0).unwrap());
        });
        println!("{}", quick.report());
    } else {
        eprintln!("artifacts missing — HLO benches skipped");
    }

    println!("{}", runner.report());

    // paper runtime checkpoints
    let evals_per_sec = 1e9
        / runner
            .results()
            .iter()
            .find(|r| r.name.contains("evaluate"))
            .unwrap()
            .ns_per_iter
            .mean;
    println!("SA inner loop: {evals_per_sec:.0} evals/s (paper needs >= 8.3K/s for 500K < 1 min)");
    report::write_text(
        "perf_hotpath.txt",
        &format!("{}\nevals_per_sec={evals_per_sec:.0}\n", runner.report()),
    );
}

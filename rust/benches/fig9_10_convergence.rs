//! Figs. 9 and 10: convergence behavior of SA and RL over 10 seeds, for
//! case (i) (64-chiplet cap, Fig. 9) and case (ii) (128, Fig. 10).
//!
//! Quick mode: 10 SA seeds × 100K iters (full: 500K) and 4 RL seeds ×
//! 24K steps (full: 10 × 250K). Emits
//! `bench_results/fig{9,10}_{sa,rl}_convergence.csv`.

use chiplet_gym::cost::Calib;
use chiplet_gym::gym::ChipletGymEnv;
use chiplet_gym::model::space::DesignSpace;
use chiplet_gym::opt::sa::{simulated_annealing, SaConfig};
use chiplet_gym::report;
use chiplet_gym::rl::{train_ppo, PpoConfig};
use chiplet_gym::runtime::Engine;
use chiplet_gym::util::stats::Summary;

fn main() {
    let full = std::env::var("CHIPLET_GYM_FULL").is_ok();
    let sa_iters = if full { 500_000 } else { 100_000 };
    let sa_seeds: Vec<u64> = (0..10).collect();
    let rl_steps = if full { 250_000 } else { 24_576 };
    let rl_seeds: Vec<u64> = if full { (0..10).collect() } else { (0..4).collect() };

    let engine = Engine::discover().ok();
    if engine.is_none() {
        eprintln!("artifacts missing — RL curves skipped, SA only");
    }
    let calib = Calib::default();

    for (fig, space) in [(9, DesignSpace::case_i()), (10, DesignSpace::case_ii())] {
        println!("=== Fig. {fig}: case {} (cap {}) ===", if fig == 9 { "i" } else { "ii" }, space.chiplet_cap);

        // ---- SA, 10 seeds ----
        let mut csv = report::csv(
            &format!("fig{fig}_sa_convergence.csv"),
            &["seed", "iteration", "best_objective"],
        );
        let mut sa_bests = Vec::new();
        let t0 = std::time::Instant::now();
        for &seed in &sa_seeds {
            let cfg = SaConfig {
                iterations: sa_iters,
                trace_every: sa_iters / 100,
                ..SaConfig::default()
            };
            let trace = simulated_annealing(&space, &calib, &cfg, seed);
            for &(iter, obj) in &trace.history {
                csv.row(&[seed as f64, iter as f64, obj]).unwrap();
            }
            sa_bests.push(trace.best_eval.reward);
        }
        csv.flush().unwrap();
        let s = Summary::of(&sa_bests);
        println!(
            "SA : {} seeds x {sa_iters} iters in {:.1}s -> best range [{:.1}, {:.1}], mean {:.1}",
            sa_seeds.len(),
            t0.elapsed().as_secs_f64(),
            s.min,
            s.max,
            s.mean
        );

        // ---- RL, seeds ----
        if let Some(engine) = &engine {
            let mut csv = report::csv(
                &format!("fig{fig}_rl_convergence.csv"),
                &["seed", "timesteps", "ep_rew_mean", "cost_value"],
            );
            let mut rl_bests = Vec::new();
            let t0 = std::time::Instant::now();
            for &seed in &rl_seeds {
                let mut cfg = PpoConfig::from_manifest(engine);
                cfg.total_timesteps = rl_steps;
                let mut env = ChipletGymEnv::new(space, calib.clone(), cfg.episode_len);
                let trace = train_ppo(engine, &mut env, &cfg, seed).expect("ppo");
                for st in &trace.history {
                    csv.row(&[
                        seed as f64,
                        st.timesteps as f64,
                        st.ep_rew_mean,
                        st.cost_value,
                    ])
                    .unwrap();
                }
                rl_bests.push(trace.best_reward);
            }
            csv.flush().unwrap();
            let s = Summary::of(&rl_bests);
            println!(
                "RL : {} seeds x {rl_steps} steps in {:.1}s -> best range [{:.1}, {:.1}], mean {:.1}",
                rl_seeds.len(),
                t0.elapsed().as_secs_f64(),
                s.min,
                s.max,
                s.mean
            );
        }
        println!(
            "(paper Fig. {fig}: case {} converges to ~{} band)",
            if fig == 9 { "i" } else { "ii" },
            if fig == 9 { "178-185 (RL) / 151-176 (SA)" } else { "188-194 (RL) / 170-188 (SA)" }
        );
        println!();
    }
}

//! Certified-search performance: bound throughput and branch-and-bound
//! end-to-end cost.
//!
//! Times (a) the root bound of the full case (i) space — the one
//! expensive geometry-enumerating bound a certification run pays once,
//! (b) a deep-prefix bound — the per-child cost every expansion pays,
//! (c) a complete certify of a shrunk (~49K-point) space against the
//! cost of plain exhaustive enumeration of the same space, and (d) one
//! budgeted warm-started run over the full space. Writes
//! `BENCH_bnb.json` under `bench_results/` with the timings plus the
//! certificate counters, to seed the perf trajectory across PRs.

use chiplet_gym::cost::{partial_upper_bound, Calib, HeadDomains};
use chiplet_gym::model::space::paper_points::table6_case_i;
use chiplet_gym::model::space::DesignSpace;
use chiplet_gym::opt::exhaustive::exhaustive_domains;
use chiplet_gym::opt::search::{BnbConfig, BnbDriver, CostObjective};
use chiplet_gym::report;
use chiplet_gym::util::bench::{fmt_ns, Runner};

fn main() {
    let space = DesignSpace::case_i();
    let calib = Calib::default();
    let full = HeadDomains::full(&space);
    let shrunk = HeadDomains::capped(&space, &[3, 4, 4, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 1]);

    let mut runner = Runner::quick();

    // (a) the root bound enumerates all 3 x 128 x 63 geometry combos.
    runner.bench("root bound (full case i)", || {
        std::hint::black_box(partial_upper_bound(&calib, &space, &full, &[]));
    });
    let root_ns = runner.results().last().unwrap().ns_per_iter.mean;

    // (b) a deep prefix collapses the geometry product to one combo.
    let deep: Vec<usize> = table6_case_i()[..6].to_vec();
    runner.bench("deep-prefix bound (6 heads fixed)", || {
        std::hint::black_box(partial_upper_bound(&calib, &space, &full, &deep));
    });
    let deep_ns = runner.results().last().unwrap().ns_per_iter.mean;

    // (c) certified optimum of a ~49K-point space vs brute force.
    let mut shrunk_cert = None;
    runner.bench("certify shrunk space (49K points)", || {
        let driver = BnbDriver::new(calib.clone(), shrunk.clone());
        let mut obj = CostObjective::new(&space, &calib);
        let out = driver.certify(&space, &mut obj);
        shrunk_cert = Some(out.certification());
        std::hint::black_box(out.best_action);
    });
    let certify_ns = runner.results().last().unwrap().ns_per_iter.mean;
    runner.bench("exhaustive oracle, same space", || {
        let out = exhaustive_domains(&space, &calib, &shrunk);
        std::hint::black_box(out.best_action);
    });
    let oracle_ns = runner.results().last().unwrap().ns_per_iter.mean;

    // (d) one budgeted full-space run, warm-started from Table 6.
    let max_nodes = 5_000u64;
    let mut full_cert = None;
    runner.bench("budgeted certify (full case i)", || {
        let mut driver = BnbDriver::new(calib.clone(), full.clone());
        driver.config = BnbConfig { max_nodes, prune: true };
        driver.warm_start = Some(table6_case_i().to_vec());
        let mut obj = CostObjective::new(&space, &calib);
        let out = driver.certify(&space, &mut obj);
        full_cert = Some(out.certification());
        std::hint::black_box(out.best_action);
    });
    let full_ns = runner.results().last().unwrap().ns_per_iter.mean;
    println!("{}", runner.report());

    let sc = shrunk_cert.expect("shrunk certify ran");
    let fc = full_cert.expect("full certify ran");
    println!(
        "shrunk: {} expanded / {} pruned / {} leaf evals (vs {:.0} brute-force), \
         certify {} vs oracle {}",
        sc.nodes_expanded,
        sc.nodes_pruned,
        sc.leaf_evals,
        shrunk.cardinality(),
        fmt_ns(certify_ns),
        fmt_ns(oracle_ns)
    );
    println!(
        "full:   {} expanded / {} pruned -> gap {:.4} in {}",
        fc.nodes_expanded,
        fc.nodes_pruned,
        fc.optimality_gap,
        fmt_ns(full_ns)
    );

    let mut json = String::from("{\n");
    json.push_str(&format!("  \"root_bound_ns\": {root_ns:.0},\n"));
    json.push_str(&format!("  \"deep_prefix_bound_ns\": {deep_ns:.0},\n"));
    json.push_str(&format!(
        "  \"shrunk\": {{\"points\": {:.0}, \"certify_ns\": {certify_ns:.0}, \
         \"oracle_ns\": {oracle_ns:.0}, \"nodes_expanded\": {}, \"nodes_pruned\": {}, \
         \"leaf_evals\": {}, \"optimality_gap\": {}}},\n",
        shrunk.cardinality(),
        sc.nodes_expanded,
        sc.nodes_pruned,
        sc.leaf_evals,
        sc.optimality_gap,
    ));
    json.push_str(&format!(
        "  \"full_budgeted\": {{\"max_nodes\": {max_nodes}, \"certify_ns\": {full_ns:.0}, \
         \"nodes_expanded\": {}, \"nodes_pruned\": {}, \"optimality_gap\": {:.6}, \
         \"complete\": {}}}\n}}\n",
        fc.nodes_expanded,
        fc.nodes_pruned,
        fc.optimality_gap,
        fc.complete,
    ));
    let path = report::write_text("BENCH_bnb.json", &json);
    println!("wrote {}", path.display());
}

//! Cost-model evaluation throughput: full path vs the delta fast path.
//!
//! Replays the optimizers' characteristic move — a long single-head
//! mutation walk around a Table 6 design point — once through
//! `cost::evaluate_action` and once through `cost::delta::DeltaEvaluator`,
//! on the case (i), case (ii) and learned-placement spaces. Reports
//! ns/eval for both paths plus the speedup (the acceptance bar is ≥ 2×
//! for single-head mutations), sanity-checks bitwise equality before
//! timing, and writes `BENCH_cost.json` (plus a CSV) under
//! `bench_results/` for the committed perf trajectory.

use chiplet_gym::cost::{evaluate_action, Calib, DeltaEvaluator};
use chiplet_gym::model::space::{paper_points, DesignSpace, ACTION_DIMS, N_HEADS};
use chiplet_gym::report;
use chiplet_gym::util::bench::{fmt_ns, Runner};
use chiplet_gym::util::Rng;

const WALK_STEPS: usize = 20_000;

/// The walk: `WALK_STEPS` actions, each differing from its predecessor
/// in exactly one link-parameter head (3..14) — the SA/greedy inner
/// move. Geometry and placement heads stay fixed so the walk measures
/// the delta path itself, not its fallback.
fn single_head_walk(start: Vec<usize>, seed: u64) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(seed);
    let mut walk = Vec::with_capacity(WALK_STEPS);
    let mut a = start;
    for _ in 0..WALK_STEPS {
        let h = 3 + rng.below((N_HEADS - 3) as u64) as usize;
        let dim = ACTION_DIMS[h];
        a[h] = (a[h] + 1 + rng.below(dim as u64 - 1) as usize) % dim;
        walk.push(a.clone());
    }
    walk
}

struct CaseResult {
    name: &'static str,
    full_ns: f64,
    delta_ns: f64,
    fast_rate: f64,
}

impl CaseResult {
    fn speedup(&self) -> f64 {
        self.full_ns / self.delta_ns
    }
}

fn run_case(
    runner: &mut Runner,
    name: &'static str,
    space: &DesignSpace,
    start: Vec<usize>,
) -> CaseResult {
    let calib = Calib::default();
    let walk = single_head_walk(start, 0xC0);

    // Bitwise-equality sanity pass before timing anything.
    let mut check = DeltaEvaluator::default();
    for a in &walk {
        let fast = check.evaluate(&calib, space, a);
        let full = evaluate_action(&calib, space, a);
        assert_eq!(fast.reward.to_bits(), full.reward.to_bits(), "{name}: delta != full");
    }

    runner.bench(&format!("{name}: full x{WALK_STEPS}"), || {
        let mut acc = 0.0f64;
        for a in &walk {
            acc += evaluate_action(&calib, space, a).reward;
        }
        std::hint::black_box(acc);
    });
    let full_ns = runner.results().last().unwrap().ns_per_iter.mean / WALK_STEPS as f64;

    let mut fast_rate = 0.0;
    runner.bench(&format!("{name}: delta x{WALK_STEPS}"), || {
        let mut delta = DeltaEvaluator::default();
        let mut acc = 0.0f64;
        for a in &walk {
            acc += delta.evaluate(&calib, space, a).reward;
        }
        fast_rate = delta.fast_rate();
        std::hint::black_box(acc);
    });
    let delta_ns = runner.results().last().unwrap().ns_per_iter.mean / WALK_STEPS as f64;

    let r = CaseResult { name, full_ns, delta_ns, fast_rate };
    println!(
        "{name:>12}: full {} / delta {} per eval => {:.2}x (fast rate {:.3})",
        fmt_ns(full_ns),
        fmt_ns(delta_ns),
        r.speedup(),
        fast_rate
    );
    r
}

fn main() {
    let mut runner = Runner::quick();
    let mut results = Vec::new();

    results.push(run_case(
        &mut runner,
        "case_i",
        &DesignSpace::case_i(),
        paper_points::table6_case_i().to_vec(),
    ));
    results.push(run_case(
        &mut runner,
        "case_ii",
        &DesignSpace::case_ii(),
        paper_points::table6_case_ii().to_vec(),
    ));
    // Placement space: 15-head actions with a fixed template head — the
    // walk still mutates only link heads, so the delta path applies.
    let placed_space = DesignSpace::case_i().with_placement_head();
    let mut placed_start = paper_points::table6_case_i().to_vec();
    placed_start.push(1);
    results.push(run_case(&mut runner, "placement", &placed_space, placed_start));

    println!("{}", runner.report());

    let mut csv = report::csv(
        "perf_cost.csv",
        &["case", "full_ns_per_eval", "delta_ns_per_eval", "speedup", "delta_fast_rate"],
    );
    for r in &results {
        csv.labeled_row(r.name, &[r.full_ns, r.delta_ns, r.speedup(), r.fast_rate])
            .expect("csv row");
    }
    csv.flush().expect("csv flush");

    let mut json = String::from("{\n  \"walk_steps\": ");
    json.push_str(&WALK_STEPS.to_string());
    json.push_str(",\n  \"cases\": {\n");
    for (i, r) in results.iter().enumerate() {
        json.push_str(&format!(
            "    \"{}\": {{\"full_ns_per_eval\": {:.1}, \"delta_ns_per_eval\": {:.1}, \
             \"speedup\": {:.2}, \"delta_fast_rate\": {:.3}}}{}\n",
            r.name,
            r.full_ns,
            r.delta_ns,
            r.speedup(),
            r.fast_rate,
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    json.push_str("  }\n}\n");
    let path = report::write_text("BENCH_cost.json", &json);
    println!("wrote {}", path.display());
}

//! End-to-end driver: the full three-layer system on the paper's real
//! workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```
//!
//! Exercises every layer in one run:
//!   L1/L2  the Pallas/JAX actor-critic, AOT-compiled to HLO, executed
//!          via PJRT on every policy forward and PPO update;
//!   L3     the Chiplet-Gym environment, GAE/rollouts, SA, and Alg. 1.
//!
//! Flow: (1) load + verify artifacts against the jax golden vectors,
//! (2) run Algorithm 1 (SA instances + PPO agents + exhaustive argmax),
//! (3) evaluate the winning design on the MLPerf suite vs the monolithic
//! baseline and report the paper's headline ratios. Results are appended
//! to bench_results/end_to_end.txt (EXPERIMENTS.md records a run).
//!
//! Scale: quick by default (~2 min); CHIPLET_GYM_FULL=1 for the paper's
//! full 20+20 agents at 500K/250K.

use chiplet_gym::cost::Calib;
use chiplet_gym::model::space::DesignSpace;
use chiplet_gym::opt::combined::{combined_optimize, CombinedConfig};
use chiplet_gym::opt::sa::SaConfig;
use chiplet_gym::report;
use chiplet_gym::rl::PpoConfig;
use chiplet_gym::runtime::{Engine, Golden};
use chiplet_gym::workloads::{mapping, mlperf::mlperf_suite, Monolithic};

fn main() -> anyhow::Result<()> {
    let full = std::env::var("CHIPLET_GYM_FULL").is_ok();
    let mut log = String::new();
    let mut out = |s: String| {
        println!("{s}");
        log.push_str(&s);
        log.push('\n');
    };

    // ---- (1) load artifacts, verify numerics against jax ----
    let t0 = std::time::Instant::now();
    let engine = Engine::discover()?;
    out(format!(
        "[1] engine up on '{}' in {:.1}s: {} params, {} logits, artifacts at {}",
        engine.platform(),
        t0.elapsed().as_secs_f64(),
        engine.manifest.param_count,
        engine.manifest.act_total,
        engine.artifact_dir().display()
    ));
    let golden = Golden::load(engine.artifact_dir())?;
    let params = engine.golden_params()?;
    let fwd = engine.policy_forward(&params, &golden.forward_obs)?;
    let value_err = (fwd.value[0] as f64 - golden.forward_value).abs();
    anyhow::ensure!(value_err < 1e-4, "golden forward mismatch: {value_err}");
    out(format!(
        "    golden check: PJRT value {:.6} == jax value {:.6} (err {value_err:.2e})",
        fwd.value[0], golden.forward_value
    ));

    // ---- (2) Algorithm 1 ----
    let space = DesignSpace::case_i();
    let calib = Calib::default();
    let mut ppo = PpoConfig::from_manifest(&engine);
    ppo.total_timesteps = if full { 250_000 } else { 40_960 };
    let cfg = CombinedConfig {
        sa: SaConfig {
            iterations: if full { 500_000 } else { 150_000 },
            trace_every: 0,
            ..SaConfig::default()
        },
        ppo,
        sa_seeds: if full { (0..20).collect() } else { (0..5).collect() },
        rl_seeds: if full { (0..20).collect() } else { (0..2).collect() },
    };
    let t1 = std::time::Instant::now();
    let outcome = combined_optimize(&engine, space, &calib, &cfg)?;
    out(format!(
        "[2] Algorithm 1: {} SA + {} RL agents in {:.1}s (paper: ~10 min)",
        cfg.sa_seeds.len(),
        cfg.rl_seeds.len(),
        t1.elapsed().as_secs_f64()
    ));
    for c in &outcome.candidates {
        out(format!("      {:>6} seed {:2}: {:8.2}", c.source, c.seed, c.eval.reward));
    }
    let best = space.decode(&outcome.best.action);
    let e = outcome.best.eval;
    out(format!(
        "    winner: {} seed {} -> {} | {} chiplets ({}x{} mesh), {} HBMs, obj {:.1} (paper band 178-185)",
        outcome.best.source, outcome.best.seed, best.arch.name(),
        best.n_chiplets, e.mesh_m, e.mesh_n, best.n_hbm(), e.reward
    ));

    // ---- (3) MLPerf evaluation vs monolithic ----
    let mono = Monolithic::new(&calib);
    out("[3] MLPerf (Fig. 12) — optimized chiplet system vs monolithic GPU:".into());
    let mut speedups = Vec::new();
    let mut gains = Vec::new();
    for w in mlperf_suite() {
        let u = mapping::u_chip(e.pe_per_chiplet, best.n_chiplets, &w);
        let tops = e.throughput_tops / calib.default_u_chip * u;
        let rate = tops * 1e12 / (w.gmac_per_task() * 1e9);
        let m_rate = mono.tasks_per_sec(&calib, &w);
        let eff = 1.0 / (e.e_op_pj * w.gmac_per_task() * 1e-3);
        let m_eff = mono.tasks_per_joule(&w);
        speedups.push(rate / m_rate);
        gains.push(eff / m_eff);
        out(format!(
            "      {:>13}: {:>12.0} inf/s ({:.2}x mono)   {:>8.1} inf/J ({:.2}x mono)",
            w.name, rate, rate / m_rate, eff, eff / m_eff
        ));
    }
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
    out(format!(
        "    headline: {:.2}x throughput (paper 1.52x), {:.2}x energy eff (paper 3.7x),",
        mean(&speedups),
        mean(&gains)
    ));
    out(format!(
        "              {:.4}x die cost (paper 0.01x), {:.2}x package cost (paper 1.62x)",
        e.die_cost / mono.die_cost,
        e.pkg_cost / mono.pkg_cost
    ));
    out(format!("total wall time {:.1}s", t0.elapsed().as_secs_f64()));

    let path = report::write_text("end_to_end.txt", &log);
    println!("\nrun log written to {}", path.display());
    Ok(())
}

//! Quickstart: evaluate a design point, run a short SA, inspect results.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! No artifacts needed — this exercises the analytical model and the SA
//! optimizer only. See `end_to_end.rs` for the full three-layer flow.

use chiplet_gym::cost::{evaluate, Calib};
use chiplet_gym::model::space::{paper_points, DesignSpace};
use chiplet_gym::opt::sa::{simulated_annealing, SaConfig};

fn main() {
    // 1. The design space of Table 1 (case i: at most 64 AI chiplets).
    let space = DesignSpace::case_i();
    println!(
        "design space: 14 parameters, {:.2e} design points",
        space.cardinality()
    );

    // 2. Evaluate the paper's own Table 6 optimum under the PPAC model.
    let calib = Calib::default();
    let point = space.decode(&paper_points::table6_case_i());
    let eval = evaluate(&calib, &point);
    println!("\npaper's Table 6 case (i) design point:");
    println!("  {} x {} chiplets ({}x{} mesh), {} HBMs",
        point.n_chiplets, "1", eval.mesh_m, eval.mesh_n, point.n_hbm());
    println!("  area/chiplet   {:.1} mm2 (yield {:.1}%)", eval.area_per_chiplet, eval.die_yield * 100.0);
    println!("  throughput     {:.1} TMAC/s (peak {:.1})", eval.throughput_tops, eval.peak_tops);
    println!("  energy/op      {:.2} pJ", eval.e_op_pj);
    println!("  package cost   {:.1} (eq. 16 units)", eval.pkg_cost);
    println!("  reward (eq.17) {:.1}", eval.reward);

    // 3. Let simulated annealing (Alg. 2) search the space for 100K iters.
    let cfg = SaConfig {
        iterations: 100_000,
        trace_every: 10_000,
        ..SaConfig::default()
    };
    let t0 = std::time::Instant::now();
    let trace = simulated_annealing(&space, &calib, &cfg, 0);
    println!(
        "\nSA: {} iterations in {:.2}s ({:.1}M evals/s)",
        cfg.iterations,
        t0.elapsed().as_secs_f64(),
        cfg.iterations as f64 / t0.elapsed().as_secs_f64() / 1e6
    );
    for (iter, best) in &trace.history {
        println!("  iter {iter:>7}: best {best:.1}");
    }
    let best = space.decode(&trace.best_action);
    println!(
        "\nSA optimum: {} with {} chiplets, {} HBMs -> objective {:.1}",
        best.arch.name(),
        best.n_chiplets,
        best.n_hbm(),
        trace.best_eval.reward
    );
    println!("(paper's optimizer lands in the 178-185 band for case (i))");
}

//! Ablation study: sensitivity of the paper's headline claims to the
//! calibration constants DESIGN.md §4 back-derives.
//!
//! ```bash
//! cargo run --release --example ablation
//! ```
//!
//! For each knob (TSV keep-out, HBM deliverable bandwidth, latency
//! hiding, KGD exponent, bonding yield) the sweep re-runs a short SA and
//! re-evaluates the headline ratios, showing which conclusions are robust
//! (architecture choice, die-cost collapse) and which are calibration-
//! sensitive (exact throughput gain).

use chiplet_gym::cost::{evaluate, Calib};
use chiplet_gym::model::space::{paper_points, DesignSpace};
use chiplet_gym::opt::sa::{simulated_annealing, SaConfig};
use chiplet_gym::util::table::Table;
use chiplet_gym::workloads::Monolithic;

fn headline(calib: &Calib) -> (f64, f64, f64, &'static str) {
    let space = DesignSpace::case_i();
    let e = evaluate(calib, &space.decode(&paper_points::table6_case_i()));
    let mono = Monolithic::new(calib);
    let cfg = SaConfig { iterations: 60_000, trace_every: 0, ..SaConfig::default() };
    let sa = simulated_annealing(&space, calib, &cfg, 0);
    let arch = space.decode(&sa.best_action).arch.name();
    (
        e.peak_tops / mono.peak_tops,      // logic-density / peak gain
        mono.die_cost / e.die_cost,        // die-cost collapse
        sa.best_eval.reward,               // optimizer best
        arch,
    )
}

fn main() {
    let base = Calib::default();
    let mut t = Table::new([
        "ablation", "value", "peak gain (1.52x)", "die cost (76x)",
        "SA best (185)", "optimum arch",
    ]);

    let mut row = |label: &str, value: String, c: &Calib| {
        let (gain, die, best, arch) = headline(c);
        t.row([
            label.to_string(),
            value,
            format!("{gain:.2}x"),
            format!("{die:.0}x"),
            format!("{best:.1}"),
            arch.to_string(),
        ]);
    };

    row("baseline", "-".into(), &base);

    for keepout in [0.0, 0.06, 0.20] {
        let mut c = base.clone();
        c.tsv_keepout_frac = keepout;
        row("tsv_keepout_frac", format!("{keepout}"), &c);
    }
    for bw in [12.0, 48.0] {
        let mut c = base.clone();
        c.hbm_deliverable_tbps = bw;
        row("hbm_deliverable_tbps", format!("{bw}"), &c);
    }
    for hide in [16.0, 256.0] {
        let mut c = base.clone();
        c.latency_hiding_ops = hide;
        row("latency_hiding_ops", format!("{hide}"), &c);
    }
    for q in [2.0, 2.5] {
        let mut c = base.clone();
        c.kgd_exponent = q;
        row("kgd_exponent", format!("{q}"), &c);
    }
    for y in [0.98, 1.0] {
        let mut c = base.clone();
        c.bond_yield = y;
        c.perfect_bonding = y >= 1.0;
        row("bond_yield", format!("{y}"), &c);
    }

    t.print();
    println!("\nrobust: 5.5D logic-on-logic optimum and the >40x die-cost collapse");
    println!("sensitive: exact peak gain tracks tsv_keepout; SA best tracks hbm bw");
}

//! HBM placement sweep — the Fig. 4 study as a runnable example.
//!
//! ```bash
//! cargo run --release --example placement_sweep
//! ```
//!
//! Sweeps all 2^6 − 1 HBM placement combinations for the case (i) layout
//! and shows how partitioning memory across multiple locations cuts the
//! worst-case supply hops (the paper's 6 → 3 hop illustration) and what
//! that does to throughput and reward.

use chiplet_gym::cost::{evaluate, Calib};
use chiplet_gym::mesh::grid::MeshGrid;
use chiplet_gym::model::space::{paper_points, DesignSpace};
use chiplet_gym::util::table::Table;

fn main() {
    let space = DesignSpace::case_i();
    let calib = Calib::default();
    let base = paper_points::table6_case_i();

    println!("sweeping 63 HBM placement masks on the Table 6 case (i) design\n");
    let mut rows: Vec<(u8, usize, usize, f64, f64, f64)> = Vec::new();
    for mask in 1u8..=63 {
        let mut action = base;
        action[2] = mask as usize - 1;
        let p = space.decode(&action);
        let grid = MeshGrid::new(p.n_footprints(), &p.hbm_locs());
        let e = evaluate(&calib, &p);
        rows.push((
            p.hbm_mask,
            p.n_hbm(),
            grid.max_hbm_hops(),
            grid.mean_hbm_hops(),
            e.throughput_tops,
            e.reward,
        ));
    }

    // Fig. 4 narrative: single left HBM vs the 5-way spread.
    let single_left = rows.iter().find(|r| r.0 == 0b000001).unwrap();
    let spread5 = rows.iter().find(|r| r.0 == 0b011111).unwrap();
    println!(
        "Fig. 4 checkpoints: 1 HBM @ left -> {} worst-case hops; 5 spread HBMs -> {} hops",
        single_left.2, spread5.2
    );

    rows.sort_by(|a, b| b.5.partial_cmp(&a.5).unwrap());
    let mut t = Table::new([
        "mask", "n_hbm", "max hops", "mean hops", "throughput", "reward",
    ]);
    println!("\ntop 10 placements by reward:");
    for r in rows.iter().take(10) {
        t.row([
            format!("{:06b}", r.0),
            format!("{}", r.1),
            format!("{}", r.2),
            format!("{:.2}", r.3),
            format!("{:.1}", r.4),
            format!("{:.1}", r.5),
        ]);
    }
    t.print();

    let mut worst = Table::new([
        "mask", "n_hbm", "max hops", "mean hops", "throughput", "reward",
    ]);
    println!("\nbottom 3:");
    for r in rows.iter().rev().take(3) {
        worst.row([
            format!("{:06b}", r.0),
            format!("{}", r.1),
            format!("{}", r.2),
            format!("{:.2}", r.3),
            format!("{:.1}", r.4),
            format!("{:.1}", r.5),
        ]);
    }
    worst.print();
    println!("\n(the paper's chosen 4-HBM spread trades one stack of area for 2-hop supply)");
}

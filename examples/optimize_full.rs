//! Full Algorithm 1 run — the paper's production optimizer configuration.
//!
//! ```bash
//! make artifacts && cargo run --release --example optimize_full          # quick
//! CHIPLET_GYM_FULL=1 cargo run --release --example optimize_full        # paper scale
//! cargo run --release --example optimize_full -- --case ii --seeds 0,1,2
//! ```
//!
//! Runs N SA instances (Alg. 2) and N PPO agents (Table 5) with distinct
//! seeds, then the exhaustive argmax over all outputs (Alg. 1), for both
//! chiplet caps, and prints the optimized parameters Table-6 style.

use chiplet_gym::config::RunConfig;
use chiplet_gym::cost::evaluate;
use chiplet_gym::opt::combined::{combined_optimize, CombinedConfig};
use chiplet_gym::rl::PpoConfig;
use chiplet_gym::runtime::Engine;
use chiplet_gym::util::cli::Args;
use chiplet_gym::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let full = std::env::var("CHIPLET_GYM_FULL").is_ok();
    let mut cfg = RunConfig::default();
    cfg.apply_args(&args);
    if !full && args.get("seeds").is_none() {
        cfg.sa_seeds = (0..8).collect();
        cfg.rl_seeds = (0..3).collect();
        cfg.sa.iterations = 150_000;
        cfg.ppo_total_timesteps = 40_960;
    }

    let engine = Engine::discover()?;
    let mut ppo = PpoConfig::from_manifest(&engine);
    ppo.total_timesteps = cfg.ppo_total_timesteps;
    ppo.episode_len = cfg.ppo_episode_len;
    ppo.ent_coef = cfg.ppo_ent_coef;
    let combined = CombinedConfig {
        sa: cfg.sa,
        ppo,
        sa_seeds: cfg.sa_seeds.clone(),
        rl_seeds: cfg.rl_seeds.clone(),
    };

    println!(
        "Algorithm 1 on case ({}): {} SA x {} iters, {} PPO x {} steps",
        if cfg.chiplet_cap == 64 { "i" } else { "ii" },
        combined.sa_seeds.len(),
        combined.sa.iterations,
        combined.rl_seeds.len(),
        combined.ppo.total_timesteps,
    );
    let t0 = std::time::Instant::now();
    let out = combined_optimize(&engine, cfg.space(), &cfg.calib, &combined)?;
    println!("finished in {:.1}s (paper: ~10 min for 20+20)", t0.elapsed().as_secs_f64());

    let sa: Vec<f64> = out.candidates.iter().filter(|c| c.source == "SA").map(|c| c.eval.reward).collect();
    let rl: Vec<f64> = out.candidates.iter().filter(|c| c.source == "RL").map(|c| c.eval.reward).collect();
    if !sa.is_empty() {
        let s = Summary::of(&sa);
        println!("SA bests: [{:.1}, {:.1}] mean {:.1}", s.min, s.max, s.mean);
    }
    if !rl.is_empty() {
        let s = Summary::of(&rl);
        println!("RL bests: [{:.1}, {:.1}] mean {:.1}", s.min, s.max, s.mean);
    }

    let p = cfg.space().decode(&out.best.action);
    let e = evaluate(&cfg.calib, &p);
    println!("\noptimized parameters ({} seed {}):", out.best.source, out.best.seed);
    println!("  architecture   {}", p.arch.name());
    println!("  chiplets       {} ({}x{} mesh of {} footprints)", p.n_chiplets, e.mesh_m, e.mesh_n, e.n_footprints);
    println!("  HBM            {} @ {:?}", p.n_hbm(), p.hbm_locs());
    println!("  AI2AI 2.5D     {} {} Gbps x {} ({:.1} Tbps), trace {} mm",
        p.ai2ai_25d.props().name, p.ai2ai_25d_gbps, p.ai2ai_25d_links,
        p.bw_ai2ai_25d_tbps(), p.ai2ai_25d_trace_mm);
    if p.arch.uses_3d() {
        println!("  AI2AI 3D       {} {} Gbps x {} ({:.1} Tbps)",
            p.ai2ai_3d.props().name, p.ai2ai_3d_gbps, p.ai2ai_3d_links, p.bw_ai2ai_3d_tbps());
    }
    println!("  AI2HBM 2.5D    {} {} Gbps x {} ({:.1} Tbps), trace {} mm",
        p.ai2hbm.props().name, p.ai2hbm_gbps, p.ai2hbm_links,
        p.bw_ai2hbm_tbps(), p.ai2hbm_trace_mm);
    println!("  objective      {:.2}", e.reward);
    Ok(())
}

//! MLPerf evaluation (Fig. 12) of arbitrary design points.
//!
//! ```bash
//! cargo run --release --example mlperf_eval
//! cargo run --release --example mlperf_eval -- --action 2,59,29,1,19,61,0,0,22,31,1,19,97,0
//! ```
//!
//! Evaluates a design point (default: the paper's Table 6 optima for both
//! cases) on the MLPerf workloads of Table 7 and prints the comparison
//! against the monolithic baseline.

use chiplet_gym::cost::{evaluate, Calib};
use chiplet_gym::model::space::{paper_points, DesignSpace, N_HEADS};
use chiplet_gym::util::cli::Args;
use chiplet_gym::util::table::{fnum, Table};
use chiplet_gym::workloads::{mapping, mlperf::mlperf_suite, Monolithic};

fn main() {
    let args = Args::from_env();
    let calib = Calib::default();
    let mono = Monolithic::new(&calib);

    let systems: Vec<(String, DesignSpace, [usize; N_HEADS])> =
        if let Some(spec) = args.get("action") {
            let parts: Vec<usize> = spec
                .split(',')
                .map(|p| p.trim().parse().expect("--action: 14 ints"))
                .collect();
            assert_eq!(parts.len(), N_HEADS);
            let mut a = [0usize; N_HEADS];
            a.copy_from_slice(&parts);
            vec![("custom".into(), DesignSpace::case_ii(), a)]
        } else {
            vec![
                ("60-chiplet (Table 6 i)".into(), DesignSpace::case_i(),
                 paper_points::table6_case_i()),
                ("112-chiplet (Table 6 ii)".into(), DesignSpace::case_ii(),
                 paper_points::table6_case_ii()),
            ]
        };

    println!(
        "monolithic baseline: {:.0} mm2, {:.0} TMAC/s peak, yield {:.0}%, E_op {:.2} pJ\n",
        mono.die_mm2,
        mono.peak_tops,
        mono.die_yield * 100.0,
        mono.e_op_pj
    );

    for (name, space, action) in systems {
        let p = space.decode(&action);
        let e = evaluate(&calib, &p);
        println!(
            "=== {name}: {} | {} chiplets, {} HBMs, {:.1} TMAC/s effective ===",
            p.arch.name(),
            p.n_chiplets,
            p.n_hbm(),
            e.throughput_tops
        );
        let mut t = Table::new([
            "benchmark", "U_chip", "inf/s", "vs mono", "inf/J", "vs mono",
        ]);
        for w in mlperf_suite() {
            let u = mapping::u_chip(e.pe_per_chiplet, p.n_chiplets, &w);
            let tops = e.throughput_tops / calib.default_u_chip * u;
            let rate = tops * 1e12 / (w.gmac_per_task() * 1e9);
            let eff = 1.0 / (e.e_op_pj * w.gmac_per_task() * 1e-3);
            t.row([
                w.name.to_string(),
                format!("{u:.2}"),
                fnum(rate),
                format!("{:.2}x", rate / mono.tasks_per_sec(&calib, &w)),
                fnum(eff),
                format!("{:.2}x", eff / mono.tasks_per_joule(&w)),
            ]);
        }
        t.print();
        println!(
            "die cost {:.4}x mono, package cost {:.2}x mono\n",
            e.die_cost / mono.die_cost,
            e.pkg_cost / mono.pkg_cost
        );
    }
}
